"""Hierarchical controller — the fat-tree's pod structure in the control
plane (DESIGN.md §12).

The flat :class:`~repro.core.controller.ClusterController` owns every host,
every ledger row and one minnow heap; at fleet scale its per-event
``advance`` walks all n workers and every placement scans one global
surface.  This module shards that state machine along the topology:

* :class:`PodController` — the pod-scope reusable unit: the pod's host
  slice, its :class:`~repro.core.timeslot.TimeSlotLedger` shard (the
  pod-internal link rows, own rolling window and §7 origin shift), its
  per-pod counter group, and — in pod-affine mode — its own
  :class:`~repro.core.controller.ClusterState` whose wavefront planner
  plans the pod's arrivals concurrently with every other pod's.
* :class:`HierarchicalState` — an implementation of the
  :class:`~repro.core.controller.SchedulingSurface` protocol over per-pod
  shards: a lazily-clamped idle view plus per-pod lazy minnow structures,
  so the clock advances in O(pods) instead of O(workers) while every value
  any policy reads is bit-identical to the flat, eagerly-clamped state.
* :class:`HierarchicalController` — the root: it owns only the
  core/aggregation (boundary) ledger shard, routes cross-pod placements,
  and periodically rebalances load between pods with the same
  compressed-column residual scoring ``core.reroute`` uses.

Two modes, one byte-parity contract:

* **exact** (default) — placements run the unmodified
  :class:`~repro.core.controller.BassPolicy` Algorithm-1 state machine
  over :class:`HierarchicalState`.  Because the sharded ledger facade is
  float-exact against the flat ledger and the lazy idle/minnow structures
  resolve the same ``(idle, name)`` order, schedule dumps diff empty
  against the flat controller on *any* workload — single-pod or
  cross-pod — as long as the rebalancer is off (it requires affinity).
* **affine** (``affinity=True``) — each task is homed to the pod holding
  most of its replicas and placed by that pod's own state machine against
  the pod shard only; the root handles replica-less and rebalanced tasks
  over the full fabric.  This trades the global Eq.-(1) argmin for pod
  locality and is the mode the rebalancer operates in.

Faults, telemetry, multipath and speculation stay flat-controller
features: the hierarchy schedules healthy fabrics (v1), and the flat
controller remains the oracle for everything else.
"""
from __future__ import annotations

import copy
import heapq
from dataclasses import replace as dc_replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from ..obs import Registry
from .controller import (
    _EPS,
    BassPolicy,
    ClusterState,
    EventQueue,
    JobRecord,
    MinnowHeap,
    choose_source,
)
from .tasks import Assignment, Schedule, Task
from .timeslot import ShardedLedger, TransferPlan
from .topology import Fabric


class PodController:
    """One pod's slice of the control plane: hosts, ledger shard, counters,
    and (pod-affine mode) its own flat scheduling state machine."""

    __slots__ = ("name", "hosts", "hosts_set", "shard", "stats", "state",
                 "row_map")

    def __init__(self, name, hosts, shard, stats, row_map):
        self.name = name
        self.hosts = list(hosts)
        self.hosts_set = frozenset(hosts)
        self.shard = shard          # the pod-internal TimeSlotLedger shard
        self.stats = stats          # per-pod counter group (repro.obs)
        self.state = None           # affine mode: pod-local ClusterState
        #: local shard row -> global (flat-numbering) row, so pod-placed
        #: transfer plans can be re-expressed in the facade's row space.
        self.row_map = row_map

    def globalize(self, a: Assignment) -> Assignment:
        """Rewrite a pod-placed assignment's transfer rows into global
        facade numbering (the committed shard bookings are untouched —
        this only normalizes the *record* so one Schedule speaks one row
        space)."""
        plan = a.transfer
        if plan is not None and plan.links:
            a.transfer = TransferPlan(
                tuple(self.row_map[r] for r in plan.links),
                plan.start, plan.end, plan.slot_fracs,
            )
        return a


class _LazyIdle(Mapping):
    """The ``ΥI_j`` view of :class:`HierarchicalState`: reads clamp against
    ``now`` lazily, so values equal what the flat state's eager per-event
    ``advance`` loop would have written — without the O(workers) walk."""

    __slots__ = ("_raw", "_state")

    def __init__(self, raw: Dict[str, float], state: "HierarchicalState"):
        self._raw = raw
        self._state = state

    def __getitem__(self, node: str) -> float:
        v = self._raw[node]
        now = self._state.now
        return v if v > now else now

    def __iter__(self):
        return iter(self._raw)

    def __len__(self) -> int:
        return len(self._raw)


class _PodMinnow:
    """Per-pod lazy minnow structure.

    The flat state keeps one exact :class:`MinnowHeap` and re-clamps every
    worker on every ``advance``.  Here each pod splits its workers into a
    heap of *future* entries (raw idle > now) and a name-ordered *stale*
    pool (raw idle <= now, effective key exactly ``(now, name)`` under the
    lazy clamp); advancing the clock costs nothing, and the pod's candidate
    minimum is an O(1) peek after an amortized sync.  The resolved
    ``(idle, name)`` order is identical to the flat heap's.
    """

    __slots__ = ("raw", "hosts", "heap", "stale_heap", "stale_set")

    def __init__(self, raw: Dict[str, float], hosts: Sequence[str],
                 now: float):
        self.raw = raw              # shared with the owning state
        self.hosts = list(hosts)
        self.rebuild(now)

    def rebuild(self, now: float) -> None:
        future = [n for n in self.hosts if self.raw[n] > now]
        self.heap = MinnowHeap({n: self.raw[n] for n in future}, future)
        stale = [n for n in self.hosts if self.raw[n] <= now]
        heapq.heapify(stale)
        self.stale_heap = stale
        self.stale_set = set(stale)

    def _sync(self, now: float) -> None:
        """Move entries the clock has passed into the stale pool."""
        h = self.heap._heap
        while h and h[0][0] <= now:
            n = h[0][1]
            self.heap.remove(n)
            heapq.heappush(self.stale_heap, n)
            self.stale_set.add(n)

    def min_key(self, now: float) -> Optional[Tuple[float, str]]:
        """The pod's minimal ``(clamped idle, name)``, or None if empty."""
        self._sync(now)
        sh, ss = self.stale_heap, self.stale_set
        while sh and sh[0] not in ss:
            heapq.heappop(sh)       # lazily deleted ghost
        best = (now, sh[0]) if sh else None
        h = self.heap._heap
        if h and (best is None or h[0] < best):
            best = h[0]
        return best

    def busy(self, node: str, finish: float, now: float) -> None:
        """Commit path: the worker's idle clock moves to ``finish``."""
        if node in self.stale_set:
            self.stale_set.discard(node)  # heap entry becomes a ghost
        elif node in self.heap._pos:
            self.heap.remove(node)
        self.raw[node] = finish
        if finish > now:
            self.heap.insert(node, finish)
        else:
            self.stale_set.add(node)
            heapq.heappush(self.stale_heap, node)


class HierarchicalState:
    """:class:`~repro.core.controller.SchedulingSurface` over pod shards.

    Same decision surface as the flat :class:`ClusterState` — ``idle``,
    ``workers_set``, ``minnow``, ``choose_source``, ``commit_local``/
    ``commit_remote`` — but idle clamping is lazy, the minnow argmin is a
    min over per-pod candidates, and ``ledger`` is the
    :class:`~repro.core.timeslot.ShardedLedger` facade.  Every value a
    policy reads is bit-identical to the flat state's, so the unmodified
    ``BassPolicy.place`` drives it (parity-tested in
    ``tests/test_hierarchy.py``).
    """

    def __init__(self, fabric: Fabric, partition, workers: Sequence[str],
                 idle: Optional[Dict[str, float]], ledger: ShardedLedger,
                 obs: Registry):
        self.fabric = fabric
        self.partition = partition
        self.workers = list(workers)
        self.workers_set = frozenset(self.workers)
        idle = idle or {}
        self._raw: Dict[str, float] = {
            n: float(idle.get(n, 0.0)) for n in self.workers
        }
        self.idle = _LazyIdle(self._raw, self)
        self.ledger = ledger
        self.now = 0.0
        self.obs = obs
        self.dataplane = None
        self.belief = None
        self.background: list = []
        self._pods: Dict[str, _PodMinnow] = {}
        unpodded = [w for w in self.workers if partition.pod_of(w) is None]
        if unpodded:
            raise ValueError(
                f"workers outside every pod cannot be sharded: {unpodded!r}"
            )
        for p in partition.pods:
            hosts = [h for h in partition.pod_hosts[p]
                     if h in self.workers_set]
            if hosts:
                self._pods[p] = _PodMinnow(self._raw, hosts, self.now)
        self._pod_list = list(self._pods.values())

    # -- queries ------------------------------------------------------------
    def minnow(self) -> str:
        best = None
        for pm in self._pod_list:
            k = pm.min_key(self.now)
            if k is not None and (best is None or k < best):
                best = k
        if best is None:
            raise ValueError("no workers")
        return best[1]

    def choose_source(self, task: Task, dst: str, at: float,
                      load: Optional[Dict[str, float]] = None, belief=None):
        return choose_source(task, dst, self.ledger, at, load=load,
                             belief=belief)

    # -- mutations ----------------------------------------------------------
    def advance(self, t: float) -> None:
        """Online clock in O(pods): the idle view clamps lazily, so only
        the rolling-horizon retire hook needs the new time."""
        if t < self.now:
            raise ValueError(f"time moves backwards: {t} < {self.now}")
        self.now = t
        self.ledger.maybe_retire(t)

    def set_idle(self, idle: Dict[str, float]) -> None:
        """Replace idle estimates wholesale.  Values below ``now`` read
        back clamped to ``now`` — the flat state reaches the same values
        one ``advance`` later, before any placement can observe them."""
        for n, v in idle.items():
            if n in self._raw:
                self._raw[n] = float(v)
        for pm in self._pod_list:
            pm.rebuild(self.now)

    def _busy(self, node: str, finish: float) -> None:
        self._pods[self.partition.host_pod[node]].busy(node, finish, self.now)

    # -- the single Assignment-emission path (SchedulingSurface) ------------
    def commit_local(self, task: Task, node: str,
                     bw_needed: Optional[float] = None) -> Assignment:
        start = self.idle[node]
        finish = start + task.compute
        self._busy(node, finish)
        return Assignment(task.tid, node, None, None, start, finish,
                          bw_needed)

    def commit_remote(self, task: Task, node: str, src: str,
                      plan: TransferPlan,
                      bw_needed: Optional[float] = None) -> Assignment:
        self.ledger.commit(plan)
        start = plan.end if plan.slot_fracs else self.idle[node]
        finish = start + task.compute
        self._busy(node, finish)
        return Assignment(task.tid, node, src, plan, start, finish,
                          bw_needed)


class _AffineStateView:
    """The slim ``controller.state`` surface in pod-affine mode: idle
    reads/refreshes fan out to the pod states (what ``serving.router``
    needs); everything else lives on the pods themselves."""

    __slots__ = ("_ctl",)

    def __init__(self, ctl: "HierarchicalController"):
        self._ctl = ctl

    @property
    def ledger(self):
        return self._ctl.ledger

    @property
    def now(self) -> float:
        return self._ctl.now

    @property
    def idle(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for pc in self._ctl.pods.values():
            out.update(pc.state.idle)
        return out

    def set_idle(self, idle: Dict[str, float]) -> None:
        for pc in self._ctl.pods.values():
            sub = {n: v for n, v in idle.items() if n in pc.state.idle}
            if sub:
                pc.state.set_idle(sub)


class HierarchicalController:
    """Root of the pod hierarchy: owns the boundary (core/aggregation)
    ledger shard, routes cross-pod placements, and rebalances pod load.

    ``affinity=False`` (default) is the byte-parity mode described in the
    module docstring; ``affinity=True`` homes each task to the pod holding
    most of its replicas and lets the pods place independently.
    ``rebalance_interval`` (affine only) arms a periodic load check: after
    ``rebalance_hysteresis`` consecutive checks where the most loaded
    pod's backlog exceeds ``rebalance_ratio``× the mean, arrivals homed to
    that pod are re-routed for one interval to the pod with the best
    boundary residual (the same compressed-column scoring
    ``core.reroute`` uses), then a cooldown suppresses re-triggering.
    """

    def __init__(
        self,
        fabric: Fabric,
        workers: Sequence[str],
        policy: "BassPolicy | str" = "bass",
        idle: Optional[Dict[str, float]] = None,
        slot_duration: float = 1.0,
        horizon_slots: int = 256,
        partition=None,
        affinity: bool = False,
        rebalance_interval: Optional[float] = None,
        rebalance_ratio: float = 1.25,
        rebalance_hysteresis: int = 2,
        rebalance_cooldown: Optional[float] = None,
        k_paths: int = 4,
    ) -> None:
        if isinstance(policy, str):
            if policy != "bass":
                raise ValueError(
                    f"hierarchical controller schedules with BASS only, "
                    f"got {policy!r}"
                )
            policy = BassPolicy()
        if not isinstance(policy, BassPolicy) or policy.multipath \
                or policy.telemetry:
            raise ValueError(
                "hierarchical controller v1 supports single-path, "
                "oracle-ledger BassPolicy only"
            )
        self.policy = policy
        self.fabric = fabric
        if partition is None:
            from ..net.fattree import pod_partition

            partition = pod_partition(fabric)
        self.partition = partition
        self.workers = list(workers)
        self.slot_duration = float(slot_duration)
        self.horizon_slots = int(horizon_slots)
        self.affinity = bool(affinity)
        if rebalance_interval is not None and not self.affinity:
            raise ValueError(
                "rebalancing requires affinity=True — exact mode is the "
                "flat controller's byte-parity oracle and must not re-home"
            )
        self.rebalance_interval = rebalance_interval
        self.rebalance_ratio = float(rebalance_ratio)
        self.rebalance_hysteresis = int(rebalance_hysteresis)
        self.rebalance_cooldown = (
            2.0 * rebalance_interval if rebalance_cooldown is None
            and rebalance_interval is not None else rebalance_cooldown
        )
        self.ledger = ShardedLedger(
            fabric, partition.groups(), slot_duration=slot_duration,
            horizon_slots=horizon_slots,
        )
        self.obs = Registry()
        self._stats = self.obs.group(
            "hier",
            ("events", "jobs", "tasks", "cross_pod", "rehomed",
             "rebalance_checks", "rebalance_triggers"),
        )
        wset = frozenset(self.workers)
        self.pods: Dict[str, PodController] = {}
        grow = self.ledger._row
        for p in partition.pods:
            hosts = [h for h in partition.pod_hosts[p] if h in wset]
            if not hosts:
                continue
            shard = self.ledger.shards[p]
            row_map = tuple(grow[name] for name in shard._names)
            self.pods[p] = PodController(
                p, hosts, shard,
                self.obs.group(f"pod.{p}",
                               ("tasks", "local", "remote",
                                "cross_in", "cross_out", "rehomed")),
                row_map,
            )
        covered = frozenset(h for pc in self.pods.values() for h in pc.hosts)
        missing = [w for w in self.workers if w not in covered]
        if missing:
            raise ValueError(f"workers outside every pod: {missing!r}")
        if self.affinity:
            idle = idle or {}
            for pc in self.pods.values():
                pc.state = ClusterState(
                    fabric, pc.hosts,
                    {n: float(idle.get(n, 0.0)) for n in pc.hosts},
                    ledger=pc.shard, slot_duration=slot_duration,
                )
            self.state = _AffineStateView(self)
        else:
            self.state = HierarchicalState(
                fabric, partition, self.workers, idle, self.ledger, self.obs
            )
        # The SDN data plane (liveness queries for serving.router; the
        # hierarchy never mutates it — faults stay a flat-controller
        # feature).  Lazy import keeps core→net one-way at module load.
        from ..net.dataplane import DataPlane

        self.dataplane = DataPlane(fabric, k=k_paths)
        self.jobs: Dict[int, JobRecord] = {}
        self._queue = EventQueue()
        self._next_jid = 0
        self.now = 0.0
        # -- rebalancer state ------------------------------------------------
        self._reb_pending = False   # a rebalance tick is queued
        self._reb_streak = 0        # consecutive imbalanced checks
        self._rehome_from: Optional[str] = None
        self._rehome_until = 0.0
        self._cooldown_until = 0.0
        self._loads: Dict[str, float] = {}
        # -- crash recovery --------------------------------------------------
        self.journal = None
        self._replaying = False
        self._in_run = False
        self.obs.register_provider("hierarchy", self._hier_obs)

    # -- write-ahead journal (per-shard WAL segments, DESIGN.md §12) --------
    def attach_journal(self, journal=None):
        """Attach a :class:`~repro.core.journal.ShardedJournal` (default)
        or a plain :class:`~repro.core.journal.Journal`: every public
        entry-point call (``submit``, ``run_until``, ``run``) is recorded
        with resolved arguments.  With a sharded journal, a submit whose
        tasks home to one pod lands in that pod's segment; the clock
        advances land in the root segment."""
        if self.journal is not None:
            raise RuntimeError("journal already attached")
        from .journal import ShardedJournal

        self.journal = journal if journal is not None else ShardedJournal()
        return self.journal

    def _journal(self, op: str, *args, shard: Optional[str] = None) -> None:
        j = self.journal
        if j is None or self._replaying or self._in_run:
            return
        from .journal import ShardedJournal

        if isinstance(j, ShardedJournal):
            j.append(op, *args, shard=shard or ShardedJournal.ROOT)
        else:
            j.append(op, *args)

    # -- entry points -------------------------------------------------------
    def submit(self, tasks: Sequence[Task], at: float = 0.0,
               jid: Optional[int] = None) -> int:
        """Queue a job (its full task list) to arrive at time ``at``."""
        if jid is None:
            jid = self._next_jid
        if jid in self.jobs:
            raise ValueError(f"duplicate job id {jid}")
        shard = None
        if self.affinity and tasks:
            shard = self._home_pod(tasks[0])
        self._journal("submit", float(at), int(jid), tuple(tasks),
                      shard=shard)
        self._next_jid = max(self._next_jid, jid + 1)
        self.jobs[jid] = JobRecord(jid, at, list(tasks))
        self._push(at, "job", (jid,))
        return jid

    def _push(self, at: float, kind: str, payload: tuple) -> None:
        if at < self.now - _EPS:
            raise ValueError(
                f"event at {at} is in the controller's past {self.now}"
            )
        self._queue.push(at, kind, payload)
        if (self.rebalance_interval is not None and not self._reb_pending
                and self._queue.n_real):
            self._arm_rebalance()

    def _arm_rebalance(self) -> None:
        """Same chain pattern as the flat controller's poll/heartbeat
        ticks: the tick re-arms only while real work is queued, so
        ``run()`` still terminates."""
        self._reb_pending = True
        self._queue.push(self.now + self.rebalance_interval, "rebalance", ())

    def run_until(self, t: float) -> None:
        """Process every queued event with fire time ≤ ``t``, in time
        order (ties: submission order) — the flat loop's contract."""
        self._journal("run_until", float(t))
        q = self._queue
        while q and q.next_at() <= t + _EPS:
            at, _seq, kind, payload = q.pop()
            self.now = max(self.now, at)
            self._clock(self.now)
            self._stats["events"] += 1
            if kind == "job":
                (jid,) = payload
                self._stats["jobs"] += 1
                with self.obs.span("hier.drain"):
                    self._drain(self.jobs[jid])
            elif kind == "rebalance":
                self._reb_pending = False
                self._on_rebalance()
                if q.n_real:
                    self._arm_rebalance()
        self.now = max(self.now, t)
        self.ledger.maybe_retire(self.now)

    def run(self) -> None:
        """Drain the event queue completely."""
        self._journal("run")
        was_in_run, self._in_run = self._in_run, True
        try:
            while self._queue:
                self.run_until(self._queue.next_at())
        finally:
            self._in_run = was_in_run

    def _clock(self, t: float) -> None:
        if self.affinity:
            # Pod states advance lazily at placement; the facade still
            # retires fully-past slots so windows stay O(horizon).
            self.ledger.maybe_retire(t)
        else:
            self.state.advance(max(self.state.now, t))

    # -- placement ----------------------------------------------------------
    def _drain(self, rec: JobRecord) -> None:
        if self.affinity:
            self._drain_affine(rec)
        else:
            # Exact mode: the unmodified Algorithm-1 state machine over the
            # hierarchical surface.  The per-task loop is bit-identical to
            # the flat controller's wavefront batch path (the wavefront's
            # own contract), so dumps diff empty against flat.
            out = []
            for task in rec.tasks:
                a = self.policy.place(task, self.state)
                self._account(task, a)
                out.append(a)
            rec.assignments = out
            rec.placed = True

    def _account(self, task: Task, a: Assignment) -> None:
        dpod = self.partition.host_pod[a.node]
        g = self.pods[dpod].stats
        g["tasks"] += 1
        self._stats["tasks"] += 1
        if a.source is None:
            g["local"] += 1
            return
        g["remote"] += 1
        spod = self.partition.host_pod.get(a.source)
        if spod != dpod:
            g["cross_in"] += 1
            self._stats["cross_pod"] += 1
            if spod in self.pods:
                self.pods[spod].stats["cross_out"] += 1

    # -- pod-affine placement ------------------------------------------------
    def _home_pod(self, task: Task) -> Optional[str]:
        """The pod holding most of the task's replicas (ties: lexically
        first pod name); None when no replica is a live pod worker."""
        counts: Dict[str, int] = {}
        for r in task.replicas:
            p = self.partition.host_pod.get(r)
            if p in self.pods and r in self.pods[p].hosts_set:
                counts[p] = counts.get(p, 0) + 1
        if not counts:
            return None
        return min(counts, key=lambda p: (-counts[p], p))

    def _rehome_active(self, home: Optional[str]) -> bool:
        return (home is not None and home == self._rehome_from
                and self.now < self._rehome_until and len(self.pods) > 1)

    def _drain_affine(self, rec: JobRecord) -> None:
        at = self.now
        by_pod: Dict[str, List[Task]] = {}
        cross: List[Tuple[Task, Optional[str]]] = []
        for task in rec.tasks:
            home = self._home_pod(task)
            if home is None or self._rehome_active(home):
                cross.append((task, home))
            else:
                by_pod.setdefault(home, []).append(task)
        by_tid: Dict[int, Assignment] = {}
        for pname in sorted(by_pod):
            pc = self.pods[pname]
            st = pc.state
            st.advance(max(st.now, at))
            # Clip each task's replica set to the pod so the pod's planner
            # (and its wavefront) only ever touches shard-local rows; the
            # home-pod argmax guarantees at least one replica survives.
            ptasks = [
                t if all(r in pc.hosts_set for r in t.replicas)
                else dc_replace(t, replicas=tuple(
                    r for r in t.replicas if r in pc.hosts_set))
                for t in by_pod[pname]
            ]
            placed = self.policy.place_batch(ptasks, st)
            for t, a in zip(by_pod[pname], placed):
                by_tid[t.tid] = pc.globalize(a)
                self._account(t, a)
        for task, home in cross:
            a = self._place_cross(task, self._pick_target(task, home),
                                  rehomed=home is not None)
            by_tid[task.tid] = a
            self._account(task, a)
        rec.assignments = [by_tid[t.tid] for t in rec.tasks]
        rec.placed = True

    def _place_cross(self, task: Task, pod_name: str,
                     rehomed: bool = False) -> Assignment:
        """Root-routed placement: destination is ``pod_name``'s minnow,
        data moves over the full fabric (boundary shard included) through
        the facade ledger."""
        pc = self.pods[pod_name]
        st = pc.state
        st.advance(max(st.now, self.now))
        dst = st.minnow()
        at_dst = st.idle[dst]
        if rehomed:
            self._stats["rehomed"] += 1
            pc.stats["rehomed"] += 1
        if dst in task.replicas or not task.replicas:
            return st.commit_local(task, dst)
        src, rows = choose_source(task, dst, self.ledger, at_dst)
        plan = self.ledger.plan_transfer(task.size, rows, not_before=at_dst)
        self.ledger.commit(plan)
        start = plan.end if plan.slot_fracs else at_dst
        finish = start + task.compute
        st.idle[dst] = finish
        st.heap.update(dst, finish)
        return Assignment(task.tid, dst, src, plan, start, finish)

    # -- rebalancer ----------------------------------------------------------
    def _pod_loads(self) -> Dict[str, float]:
        """Mean per-worker backlog (idle beyond ``now``) per pod."""
        now = self.now
        out = {}
        for p, pc in self.pods.items():
            tot = 0.0
            for n in pc.state.workers:
                v = pc.state.idle[n] - now
                if v > 0.0:
                    tot += v
            out[p] = tot / len(pc.state.workers)
        return out

    def _on_rebalance(self) -> None:
        self._stats["rebalance_checks"] += 1
        self._loads = loads = self._pod_loads()
        if len(loads) < 2:
            return
        mean = sum(loads.values()) / len(loads)
        hi = max(loads, key=lambda p: (loads[p], p))
        imbalanced = mean > 0.0 and loads[hi] > self.rebalance_ratio * mean
        if not imbalanced:
            self._reb_streak = 0
            self._rehome_from = None
            return
        self._reb_streak += 1
        if (self._reb_streak >= self.rebalance_hysteresis
                and self.now >= self._cooldown_until):
            self._stats["rebalance_triggers"] += 1
            self._rehome_from = hi
            self._rehome_until = self.now + self.rebalance_interval
            self._cooldown_until = self.now + self.rebalance_cooldown
            self._reb_streak = 0

    def _pick_target(self, task: Task, home: Optional[str]) -> str:
        """Where a cross-pod task lands: lowest-load pod first, ties broken
        by the boundary path's residual bandwidth from the task's best
        home replica — the same compressed-column ledger scoring
        ``core.reroute`` ranks failover candidates with."""
        cands = [p for p in sorted(self.pods) if p != home]
        if not cands:
            return home
        if len(cands) == 1:
            return cands[0]
        rep = None
        if home is not None:
            reps = [r for r in task.replicas
                    if self.partition.host_pod.get(r) == home]
            rep = min(reps) if reps else None
        if rep is None and task.replicas:
            rep = min(task.replicas)
        loads = self._loads
        if rep is None:
            return min(cands, key=lambda p: (loads.get(p, 0.0), p))
        scores = []
        for p in cands:
            if self.partition.host_pod.get(rep) == p:
                scores.append(float("inf"))
                continue
            rows = self.ledger.path_rows(rep, self.pods[p].hosts[0])
            scores.append(float(self.ledger.path_bandwidth(rows, self.now)))
        best = min(
            range(len(cands)),
            key=lambda i: (loads.get(cands[i], 0.0), -scores[i], cands[i]),
        )
        return cands[best]

    # -- results -------------------------------------------------------------
    def schedule(self) -> Schedule:
        """All placed assignments across jobs, as one Schedule (global
        facade row numbering in both modes)."""
        out = [a for rec in self.jobs.values() for a in rec.assignments]
        kinds = {
            t.tid: t.kind for rec in self.jobs.values() for t in rec.tasks
        }
        out.sort(key=lambda a: a.tid)
        return Schedule(out, self.ledger, kinds=kinds)

    def job_metrics(self, jid: int):
        """Per-job Table-I row relative to arrival: MT/RT/JT/LR — the flat
        controller's exact formula."""
        from .simulator import JobMetrics

        rec = self.jobs[jid]
        if not rec.placed:
            raise ValueError(f"job {jid} not placed yet (run_until?)")
        kinds = {t.tid: t.kind for t in rec.tasks}
        jt = rec.makespan - rec.submit_at
        maps = [a.finish for a in rec.assignments
                if kinds.get(a.tid, "map") == "map"]
        mt = (max(maps) - rec.submit_at) if maps else jt
        n = len(rec.assignments)
        lr = sum(1 for a in rec.assignments if a.local) / n if n else 0.0
        return JobMetrics(mt=mt, rt=jt - mt, jt=jt, lr=lr)

    # -- observability --------------------------------------------------------
    def _hier_obs(self) -> dict:
        out = {
            "pods": len(self.pods),
            "affinity": int(self.affinity),
            "boundary_links": len(self.partition.boundary_links),
            "rebalance_streak": self._reb_streak,
            "rehome_from": self._rehome_from or "",
        }
        for p, pc in sorted(self.pods.items()):
            out[f"{p}.hosts"] = len(pc.hosts)
            out[f"{p}.links"] = len(pc.shard._names)
            if self._loads:
                out[f"{p}.load"] = self._loads.get(p, 0.0)
        return out

    # -- full-fidelity snapshots + recovery (DESIGN.md §12) ------------------
    def snapshot(self):
        """A :class:`~repro.core.journal.ControllerSnapshot` of the whole
        hierarchy at the current journal position: per-shard ledger
        windows, per-pod (or lazy global) idle clocks, the event heap
        verbatim, jobs, rebalancer state and the obs counters —
        ``recover_from`` restores a byte-identical twin."""
        from .journal import ControllerSnapshot

        with self.obs.span("recovery.snapshot"):
            if self.affinity:
                idle = {
                    p: (dict(pc.state.idle), pc.state.now)
                    for p, pc in self.pods.items()
                }
            else:
                idle = (dict(self.state._raw), self.state.now)
            payload = {
                "config": {
                    "workers": list(self.workers),
                    "slot_duration": self.slot_duration,
                    "horizon_slots": self.horizon_slots,
                    "affinity": self.affinity,
                    "rebalance_interval": self.rebalance_interval,
                    "rebalance_ratio": self.rebalance_ratio,
                    "rebalance_hysteresis": self.rebalance_hysteresis,
                    "rebalance_cooldown": self.rebalance_cooldown,
                },
                "now": self.now,
                "ledger": self.ledger.dump_state(),
                "events": list(self._queue.items),
                "seq": self._queue.seq,
                "n_real": self._queue.n_real,
                "jobs": copy.deepcopy(self.jobs),
                "next_jid": self._next_jid,
                "idle": idle,
                "rebalance": (self._reb_streak, self._rehome_from,
                              self._rehome_until, self._cooldown_until,
                              dict(self._loads)),
                "obs": self.obs.dump_values(),
            }
        lsn = self.journal.lsn if self.journal is not None else 0
        return ControllerSnapshot(lsn=lsn, payload=payload)

    @classmethod
    def recover_from(cls, fabric: Fabric, snapshot,
                     journal=None) -> "HierarchicalController":
        """Restore a snapshot and replay ``journal.since(snapshot.lsn)``
        through the public entry points — byte-identical to a hierarchy
        that never crashed (property-tested in ``tests/test_hierarchy.py``).
        With a :class:`~repro.core.journal.ShardedJournal`, the per-shard
        segments are merged back into global LSN order first."""
        p = snapshot.payload
        cfg = p["config"]
        ctl = cls(
            fabric, cfg["workers"],
            slot_duration=cfg["slot_duration"],
            horizon_slots=cfg["horizon_slots"],
            affinity=cfg["affinity"],
            rebalance_interval=cfg["rebalance_interval"],
            rebalance_ratio=cfg["rebalance_ratio"],
            rebalance_hysteresis=cfg["rebalance_hysteresis"],
            rebalance_cooldown=cfg["rebalance_cooldown"],
        )
        ctl.ledger.load_state(p["ledger"])
        ctl._queue.items = list(p["events"])
        ctl._queue.seq = p["seq"]
        ctl._queue.n_real = p["n_real"]
        ctl._reb_pending = any(
            ev[2] == "rebalance" for ev in ctl._queue.items
        )
        ctl.jobs = copy.deepcopy(p["jobs"])
        ctl._next_jid = p["next_jid"]
        ctl.now = p["now"]
        if ctl.affinity:
            for pname, (idle, pnow) in p["idle"].items():
                st = ctl.pods[pname].state
                st.now = pnow
                st.set_idle(idle)
        else:
            raw, snow = p["idle"]
            ctl.state._raw.update(raw)
            ctl.state.now = snow
            for pm in ctl.state._pod_list:
                pm.rebuild(snow)
        (ctl._reb_streak, ctl._rehome_from, ctl._rehome_until,
         ctl._cooldown_until, loads) = p["rebalance"]
        ctl._loads = dict(loads)
        ctl.obs.load_values(p["obs"])
        if journal is not None:
            ctl._replaying = True
            try:
                for rec in journal.since(snapshot.lsn):
                    op, a = rec.op, rec.args
                    if op == "submit":
                        ctl.submit(list(a[2]), at=a[0], jid=a[1])
                    elif op == "run_until":
                        ctl.run_until(a[0])
                    elif op == "run":
                        ctl.run()
                    else:
                        raise ValueError(f"unknown journal op {op!r}")
            finally:
                ctl._replaying = False
            ctl.journal = journal
        return ctl
