from .pipeline import DataConfig, MemmapSource, Prefetcher, SyntheticLM
from .placement import FetchAssignment, ShardMeta, plan_epoch, prefetch_epoch, uniform_shards
