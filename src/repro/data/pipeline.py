"""Deterministic sharded data pipeline.

Design goals (1000+-node deployability):

* **Stateless addressing** — sample ``i`` of epoch ``e`` is a pure function
  of (seed, e, i); restart from a checkpointed ``step`` without replaying.
* **Sharded reads** — each data-parallel group reads only its batch slice.
* **Host-side prefetch** — a double-buffered iterator hides fetch latency;
  *which replica to fetch a shard from and when* is decided by the BASS
  placement layer (``data.placement``), honoring the TS ledger.

Two sources are provided: ``SyntheticLM`` (seeded token streams — used by
tests/examples; no tokenizer dependency) and ``MemmapSource`` (pre-tokenized
``.bin`` shards on disk, the production path).
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    n_vision_tokens: int = 0
    d_model: int = 0                 # for modality-stub embeddings
    family: str = "dense"
    enc_seq: int = 0
    task: str = "copy"               # copy | increment (increment learns in
                                     # tens of steps — used by fast CI tests)


class SyntheticLM:
    """Seeded synthetic LM stream with learnable structure (a noisy copy
    task: second half of each sequence repeats the first half) so example
    training runs show a *decreasing* loss, not noise-floor flailing."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, epoch: int, index: int) -> np.random.Generator:
        h = hashlib.blake2b(
            f"{self.cfg.seed}/{epoch}/{index}".encode(), digest_size=8
        ).digest()
        return np.random.default_rng(int.from_bytes(h, "little"))

    def sample(self, epoch: int, index: int) -> np.ndarray:
        rng = self._rng(epoch, index)
        s = self.cfg.seq_len
        if self.cfg.task == "increment":
            v = self.cfg.vocab_size - 2
            start = int(rng.integers(0, v))
            return (2 + (start + np.arange(s)) % v).astype(np.int32)
        half = s // 2
        first = rng.integers(2, self.cfg.vocab_size, size=half, dtype=np.int64)
        noise = rng.random(s - half) < 0.05
        second = first[: s - half].copy()
        second[noise] = rng.integers(2, self.cfg.vocab_size, size=int(noise.sum()))
        return np.concatenate([first, second]).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        gb = self.cfg.global_batch
        toks = np.stack([self.sample(0, step * gb + i) for i in range(gb)])
        out: Dict[str, np.ndarray] = {"tokens": toks}
        if self.cfg.family == "vlm" and self.cfg.n_vision_tokens:
            rng = self._rng(1, step)
            out["vision_embeds"] = rng.standard_normal(
                (gb, self.cfg.n_vision_tokens, self.cfg.d_model), dtype=np.float32
            )
            out["tokens"] = toks[:, : self.cfg.seq_len - self.cfg.n_vision_tokens]
        if self.cfg.family == "encdec":
            rng = self._rng(2, step)
            out["frames"] = rng.standard_normal(
                (gb, self.cfg.enc_seq, self.cfg.d_model), dtype=np.float32
            )
        return out


class MemmapSource:
    """Pre-tokenized uint16/uint32 shards (``<name>-NNNNN.bin``) — the
    production input format.  Shard→host placement comes from the manifest;
    fetch scheduling from ``data.placement``."""

    def __init__(self, root: str | Path, seq_len: int, dtype=np.uint16):
        self.root = Path(root)
        self.seq_len = seq_len
        self.dtype = dtype
        self.shards = sorted(self.root.glob("*.bin"))
        if not self.shards:
            raise FileNotFoundError(f"no .bin shards under {root}")
        self._sizes = [p.stat().st_size // np.dtype(dtype).itemsize for p in self.shards]

    def n_sequences(self) -> int:
        return sum(sz // self.seq_len for sz in self._sizes)

    def read(self, shard_idx: int, seq_idx: int) -> np.ndarray:
        mm = np.memmap(self.shards[shard_idx], dtype=self.dtype, mode="r")
        off = seq_idx * self.seq_len
        return np.asarray(mm[off : off + self.seq_len], dtype=np.int32)


class Prefetcher:
    """Double-buffered host-side prefetch around any ``batch(step)`` source."""

    def __init__(self, source, depth: int = 2):
        import queue
        import threading

        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = False
        self._step = 0
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop:
            batch = self.source.batch(self._step)
            self._q.put((self._step, batch))
            self._step += 1

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop = True
