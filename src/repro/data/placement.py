"""BASS-driven input-shard placement — the paper's technique as a
first-class feature of the training data path.

Every epoch, the controller must decide which data-parallel worker fetches
which input shard from which replica host, and *when* the DCN transfer
runs.  This is exactly the paper's Hadoop problem:

* a shard's replica holders            ↔ ``Task.replicas``
* per-worker ingest backlog (seconds)  ↔ ``ΥI_j`` (ProgressRate-estimated)
* shard fetch over host NICs + trunks  ↔ ``TM`` with TS-slot reservation
* epoch ingest completion              ↔ the makespan (Eq. 5)

``plan_epoch`` runs Algorithm 1 (or a baseline, for the ablation bench) and
returns per-worker fetch schedules; ``prefetch_epoch`` applies the Pre-BASS
refinement so transfers land *before* the step that consumes them.  Traffic
class: Q2 (data input) — below gradient sync, above checkpoints (Ex. 3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.bass import schedule_bass
from ..core.prebass import schedule_prebass
from ..core.tasks import Instance, Schedule, Task
from ..core.topology import Fabric, tpu_dcn_fabric


@dataclass(frozen=True)
class ShardMeta:
    shard_id: int
    size_bytes: float
    replicas: Tuple[str, ...]        # host names holding the shard


@dataclass
class FetchAssignment:
    shard_id: int
    worker: str
    source: Optional[str]            # None = local read
    start: float
    ready: float                     # transfer end (0 for local)
    slots: Tuple[int, ...]


def plan_epoch(
    fabric: Fabric,
    workers: Sequence[str],
    backlog: Dict[str, float],
    shards: Sequence[ShardMeta],
    decomp_seconds_per_shard: float = 0.05,
    scheduler=schedule_bass,
    slot_duration: float = 0.1,
) -> Tuple[List[FetchAssignment], Schedule]:
    """Assign every shard to a worker with bandwidth-aware BASS.

    ``decomp_seconds_per_shard`` models the host-side work after the bytes
    arrive (decompress + H2D) — the ``TP`` of Eq. (2).
    """
    tasks = [
        Task(
            tid=s.shard_id,
            size=s.size_bytes,
            compute=decomp_seconds_per_shard,
            replicas=s.replicas,
        )
        for s in shards
    ]
    inst = Instance(
        fabric=fabric,
        workers=list(workers),
        idle=dict(backlog),
        tasks=tasks,
        slot_duration=slot_duration,
    )
    sched = scheduler(inst)
    out = [
        FetchAssignment(
            shard_id=a.tid,
            worker=a.node,
            source=a.source,
            start=a.transfer.start if a.transfer else a.start,
            ready=a.transfer.end if a.transfer else 0.0,
            slots=a.transfer.slots if a.transfer else (),
        )
        for a in sched.assignments
    ]
    return out, sched


def prefetch_epoch(
    fabric: Fabric,
    workers: Sequence[str],
    backlog: Dict[str, float],
    shards: Sequence[ShardMeta],
    **kw,
) -> Tuple[List[FetchAssignment], Schedule]:
    """Pre-BASS variant: transfers pulled as early as the ledger allows."""
    return plan_epoch(
        fabric, workers, backlog, shards, scheduler=schedule_prebass, **kw
    )


def uniform_shards(
    n_shards: int,
    hosts: Sequence[str],
    size_bytes: float,
    replication: int = 3,
    seed: int = 0,
) -> List[ShardMeta]:
    rng = np.random.default_rng(seed)
    hosts = list(hosts)
    return [
        ShardMeta(
            shard_id=i,
            size_bytes=size_bytes,
            replicas=tuple(rng.choice(hosts, size=min(replication, len(hosts)), replace=False)),
        )
        for i in range(n_shards)
    ]
