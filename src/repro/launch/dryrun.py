import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run: ``lower().compile()`` every (arch × shape × mesh) cell.

Per cell this produces a JSON artifact under ``artifacts/dryrun/`` holding:

* ``memory``      — ``compiled.memory_analysis()`` per-device bytes (fit proof)
* ``cost``        — ``compiled.cost_analysis()`` (per-device, loop-once)
* ``collectives`` — trip-multiplied wire bytes by kind & fabric (ICI/DCN)
* ``accounting``  — global FLOPs/bytes from the unrolled lowering (+ the
  mamba time-scan addendum), feeding EXPERIMENTS.md §Roofline
* ``roofline``    — the three terms in seconds + dominant bottleneck

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import ARCH_NAMES, get_config, shapes_for
from ..configs.base import ShapeSpec
from ..distributed.sharding import (
    ACT_RULES_DECODE,
    ACT_RULES_SMALL_DP,
    ACT_RULES_TRAIN,
    ACT_RULES_TRAIN_OPT,
    PARAM_RULES_SMALL_DP,
    param_shardings,
    spec_for,
)

SMALL_MODEL_PARAMS = 2e8     # below this, the opt policy runs pure DP
from ..models.model import Model
from ..models.params import tree_map_defs
from ..optim.adamw import AdamW, AdamWState
from ..optim.schedule import warmup_cosine
from .hlo_analysis import (
    model_flops_estimate,
    parse_collectives,
    roofline_terms,
    ssm_scan_addendum,
)
from .inputs import decode_inputs, train_inputs
from .mesh import make_production_mesh, mesh_device_count
from .steps import make_decode_step, make_prefill_step, make_train_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"
TRAIN_ACCUM = 8


def _scope_trips(cfg, shape, accum: int) -> dict:
    trips = {}
    if cfg.scan_layers:
        if cfg.family == "hybrid":
            trips["scan_layers"] = cfg.n_layers // cfg.attn_period
        else:
            trips["scan_layers"] = cfg.n_layers
    if shape.kind == "train" and accum > 1:
        trips["scan_accum"] = accum
    if cfg.family in ("ssm", "hybrid") and shape.kind != "decode":
        trips["scan_time"] = shape.seq_len
    if cfg.attn_chunk and shape.kind != "decode":
        trips["scan_qchunk"] = max(1, shape.seq_len // cfg.attn_chunk)
    return trips


def _opt_abstract_and_shardings(params_abs, param_sh, mesh):
    m = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_abs
    )
    count = jax.ShapeDtypeStruct((), jnp.int32)
    state = AdamWState(m=m, v=m, count=count)
    rep = NamedSharding(mesh, PartitionSpec())
    sh = AdamWState(m=param_sh, v=param_sh, count=rep)
    return state, sh


def policy_rules(arch: str, shape: ShapeSpec, mesh, policy: str):
    """→ (cfg transform, param rules, activation rules) for a policy."""
    cfg = get_config(arch)
    act = dict(ACT_RULES_DECODE if shape.kind == "decode" else ACT_RULES_TRAIN)
    param_rules = None  # PARAM_RULES default
    if policy == "opt":
        # Measured lesson (§Perf): head-sharded attention + Megatron blocks
        # win for train_4k but *regress* 32k prefill (the gathered-h and
        # per-head full-length scores outweigh the savings) — so the opt
        # activation rules apply to training only; prefill keeps the
        # baseline seq-sharding and still gets the a2a MoE dispatch.
        if cfg.param_count() < SMALL_MODEL_PARAMS and shape.kind == "train":
            act = dict(ACT_RULES_SMALL_DP)
            param_rules = PARAM_RULES_SMALL_DP
        elif shape.kind == "train":
            act = dict(ACT_RULES_TRAIN_OPT)
        if cfg.n_experts:
            cfg = cfg.with_(moe_impl="a2a")
    if "pod" in mesh.shape and "batch" in act and not isinstance(act["batch"], list):
        act["batch"] = ("pod", "data")
    elif "batch" in act and not isinstance(act["batch"], list):
        act["batch"] = ("data",)
    return cfg, param_rules, act


def build_cell(arch: str, shape: ShapeSpec, mesh, accum: int = TRAIN_ACCUM,
               policy: str = "baseline"):
    """→ (jitted-but-unlowered fn, example abstract args, scope trips, cfg)."""
    cfg, param_rules, _act = policy_rules(arch, shape, mesh, policy)
    model = Model(cfg)
    defs = model.defs()
    params_abs = model.abstract()
    param_sh = param_shardings(defs, mesh, rules=param_rules)
    rep = NamedSharding(mesh, PartitionSpec())

    if shape.kind == "train":
        batch_abs, batch_sh = train_inputs(cfg, shape, mesh)
        opt = AdamW(lr=warmup_cosine(3e-4, 2000, 100_000))
        step = make_train_step(model, opt, accum=accum)
        opt_abs, opt_sh = _opt_abstract_and_shardings(params_abs, param_sh, mesh)
        metrics_sh = {"loss": rep, "grad_norm": rep}
        fn = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, metrics_sh),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs, batch_sh = train_inputs(cfg, shape, mesh)
        step = make_prefill_step(model, s_max=shape.seq_len)
        rules = dict(ACT_RULES_DECODE)
        rules["batch"] = ("pod", "data") if "pod" in mesh.shape else ("data",)
        logits_sh = NamedSharding(
            mesh,
            spec_for(
                (shape.global_batch, cfg.vocab_size), ("batch", "vocab"), mesh, rules
            ),
        )
        cache_sh = tree_map_defs(
            lambda p: NamedSharding(mesh, spec_for(p.shape, p.axes, mesh, rules)),
            model.cache_defs(shape.global_batch, shape.seq_len),
        )
        fn = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh),
            out_shardings=(logits_sh, cache_sh),
        )
        args = (params_abs, batch_abs)
    else:  # decode
        (token, pos, caches), (token_sh, pos_sh, cache_sh) = decode_inputs(
            cfg, shape, mesh
        )
        step = make_decode_step(model)
        rules = dict(ACT_RULES_DECODE)
        rules["batch"] = ("pod", "data") if "pod" in mesh.shape else ("data",)
        logits_sh = NamedSharding(
            mesh,
            spec_for(
                (shape.global_batch, cfg.vocab_size), ("batch", "vocab"), mesh, rules
            ),
        )
        fn = jax.jit(
            step,
            in_shardings=(param_sh, token_sh, pos_sh, cache_sh),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=(3,),
        )
        args = (params_abs, token, pos, caches)

    return fn, args, _scope_trips(cfg, shape, accum), cfg


def accounting_lowering(arch: str, shape: ShapeSpec):
    """Unrolled single-device lowering for global FLOPs/bytes."""
    cfg = get_config(arch).with_(scan_layers=False, attn_chunk=0)
    model = Model(cfg)
    params_abs = model.abstract()
    if shape.kind == "train":
        # accum=1: full-batch flops in one pass.
        from ..optim.schedule import constant

        step = make_train_step(model, AdamW(lr=constant(3e-4)), accum=1)
        opt_abs = jax.eval_shape(AdamW(lr=3e-4).init, params_abs)
        batch_abs, _ = train_inputs(cfg, shape, None)
        lowered = jax.jit(step).lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs, _ = train_inputs(cfg, shape, None)
        lowered = jax.jit(make_prefill_step(model, shape.seq_len)).lower(
            params_abs, batch_abs
        )
    else:
        (token, pos, caches), _ = decode_inputs(cfg, shape, None)
        lowered = jax.jit(make_decode_step(model)).lower(
            params_abs, token, pos, caches
        )
    return lowered, cfg


def run_cell(
    arch: str,
    shape: ShapeSpec,
    multi_pod: bool,
    out_dir: Path,
    force: bool = False,
    with_accounting: bool = True,
    accum: int = TRAIN_ACCUM,
    policy: str = "baseline",
) -> dict:
    mesh_tag = "pod512" if multi_pod else "pod256"
    suffix = "" if policy == "baseline" else f"__{policy}"
    out = out_dir / f"{arch}__{shape.name}__{mesh_tag}{suffix}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())

    t0 = time.time()
    record: dict = {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh_tag,
        "policy": policy,
        "ok": False,
    }
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh_device_count(mesh)
        fn, args, trips, cfg = build_cell(arch, shape, mesh, accum, policy)
        from ..distributed.actctx import activation_sharding

        _cfg2, _pr, act_rules = policy_rules(arch, shape, mesh, policy)
        with mesh, activation_sharding(mesh, act_rules):
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_gib": mem.argument_size_in_bytes / 2**30,
            "output_gib": mem.output_size_in_bytes / 2**30,
            "temp_gib": mem.temp_size_in_bytes / 2**30,
            "peak_gib": (
                mem.argument_size_in_bytes
                + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0)
                + mem.temp_size_in_bytes
            )
            / 2**30,
            "alias_gib": mem.alias_size_in_bytes / 2**30,
        }
        ca = compiled.cost_analysis() or {}
        record["cost_per_device_loop_once"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        txt = compiled.as_text()
        coll = parse_collectives(txt, trips, world=chips)
        record["collectives"] = {
            "count": coll.count(),
            "by_kind_wire_bytes": coll.by_kind(),
            "wire_bytes_ici": coll.total_wire_bytes(dcn=False),
            "wire_bytes_dcn": coll.total_wire_bytes(dcn=True),
        }
        record["scope_trips"] = trips
        record["compile_s"] = round(time.time() - t0, 1)

        if with_accounting:
            t1 = time.time()
            lowered_b, cfg_b = accounting_lowering(arch, shape)
            cb = lowered_b.cost_analysis() or {}
            add_flops, add_bytes = ssm_scan_addendum(cfg_b, shape)
            flops_global = float(cb.get("flops", 0.0)) + add_flops
            bytes_global = float(cb.get("bytes accessed", 0.0)) + add_bytes
            mf = model_flops_estimate(cfg_b, shape)
            terms = roofline_terms(
                flops_global, bytes_global, coll, chips, mf, intra_pod=256
            )
            record["accounting"] = {
                "hlo_flops_global": flops_global,
                "hlo_bytes_global": bytes_global,
                "ssm_addendum_flops": add_flops,
                "model_flops": mf,
                "accounting_s": round(time.time() - t1, 1),
            }
            record["roofline"] = terms.to_dict()
        record["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        record["compile_s"] = round(time.time() - t0, 1)

    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(record, indent=2, default=str))
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-accounting", action="store_true")
    ap.add_argument("--accum", type=int, default=TRAIN_ACCUM)
    ap.add_argument("--policy", default="baseline", choices=["baseline", "opt"])
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes_for(arch):
            if args.shape != "all" and shape.name != args.shape:
                continue
            for multi in meshes:
                tag = f"{arch} × {shape.name} × {'2x16x16' if multi else '16x16'}"
                rec = run_cell(
                    arch,
                    shape,
                    multi,
                    out_dir,
                    force=args.force,
                    with_accounting=not args.no_accounting,
                    accum=args.accum,
                    policy=args.policy,
                )
                if rec["ok"]:
                    n_ok += 1
                    mem = rec["memory"]["peak_gib"]
                    dom = rec.get("roofline", {}).get("dominant", "-")
                    print(
                        f"[OK]   {tag:64s} peak={mem:7.2f} GiB/dev "
                        f"compile={rec['compile_s']:6.1f}s dominant={dom}",
                        flush=True,
                    )
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag:64s} {rec['error']}", flush=True)
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
