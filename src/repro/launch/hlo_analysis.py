"""HLO artifact analysis for the roofline report.

Two facts about XLA cost accounting drive the design (verified by probe):

1. ``cost_analysis()`` visits each op **once** — while-loop bodies are NOT
   multiplied by trip count.  Scanned models would report 1-layer FLOPs.
2. Collective ops only exist in the *compiled* (SPMD-partitioned) module,
   and every op line carries ``metadata={op_name="…/scan_layers/while/body/…"}``
   — our ``jax.named_scope`` labels survive into the partitioned HLO.

So each dry-run cell produces TWO artifacts:

* **compiled scanned step** (the deliverable): ``memory_analysis()`` proves
  fit; its text is parsed here for the collective schedule, with each
  collective's wire bytes multiplied by the trip counts of the named scan
  scopes on its op_name path.
* **unrolled lowering** (``scan_layers=False``, no remat-free accounting
  change): ``lowered.cost_analysis()`` on the unoptimized module gives
  *global* FLOPs/bytes with every layer materialized once.  The mamba time
  scan stays a loop even there; its interior is added analytically
  (``ssm_scan_addendum``).

Wire-byte model per participating device (ring algorithms):
  all-gather: R·(g−1)/g   all-reduce: 2·M·(g−1)/g   reduce-scatter: S·(g−1)
  all-to-all: R·(g−1)/g   collective-permute: R
Group size g is parsed from ``replica_groups``; groups ≤ intra-pod size are
costed against ICI bandwidth, larger groups against DCN.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)\s+)?(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _iota_groups(n_groups: int, g: int, dims, perm):
    """Materialize an IotaReplicaGroupList: iota(prod).reshape(dims)
    .transpose(perm).reshape(n_groups, g)."""
    import numpy as np

    total = 1
    for d in dims:
        total *= d
    arr = np.arange(total).reshape(dims)
    if perm is not None:
        arr = arr.transpose(perm)
    return arr.reshape(n_groups, g)


def _group_info(line: str, world: int, pod: int):
    """→ (group size, crosses_pod) for the collective on this line."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, g = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        groups = _iota_groups(n_groups, g, dims, perm)
        crosses = bool(((groups // pod).max(axis=1) != (groups // pod).min(axis=1)).any())
        return g, crosses
    gl = _GROUPS_LIST_RE.search(line)
    if gl:
        members = [int(x) for x in gl.group(1).split(",") if x.strip()]
        crosses = len({mm // pod for mm in members}) > 1
        return max(len(members), 1), crosses
    pr = _PAIRS_RE.search(line)
    if pr:  # collective-permute pairs
        nums = [int(x) for x in re.findall(r"\d+", pr.group(1))]
        crosses = any(a // pod != b // pod for a, b in zip(nums[::2], nums[1::2]))
        return 2, crosses
    return world, world > pod


@dataclass
class Collective:
    kind: str
    result_bytes: int
    group: int
    trips: int
    wire_bytes: float
    path: str
    crosses_pod: bool = False


@dataclass
class CollectiveReport:
    ops: List[Collective] = field(default_factory=list)

    def total_wire_bytes(
        self,
        max_group: Optional[int] = None,
        min_group: int = 0,
        dcn: Optional[bool] = None,
    ) -> float:
        return sum(
            c.wire_bytes * c.trips
            for c in self.ops
            if (max_group is None or c.group <= max_group)
            and c.group > min_group
            and (dcn is None or c.crosses_pod == dcn)
        )

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for c in self.ops:
            out[c.kind] = out.get(c.kind, 0.0) + c.wire_bytes * c.trips
        return out

    def count(self) -> int:
        return len(self.ops)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute


def parse_collectives(
    hlo_text: str,
    scope_trips: Dict[str, int],
    world: int,
    pod: int = 256,
) -> CollectiveReport:
    report = CollectiveReport()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        rbytes = _shape_bytes(dtype, dims)
        group, crosses = _group_info(line, world, pod)
        onm = _OPNAME_RE.search(line)
        path = onm.group(1) if onm else ""
        trips = 1
        for label, t in scope_trips.items():
            trips *= t ** path.count(label)
        report.ops.append(
            Collective(
                kind, rbytes, group, trips,
                _wire_bytes(kind, rbytes, group), path, crosses,
            )
        )
    return report


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 per chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one-direction budget we charge)
DCN_BW = 6.25e9              # bytes/s per chip (25 GB/s NIC / 4 chips)


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_ici_s: float
    collective_dcn_s: float
    hlo_flops_global: float
    hlo_bytes_global: float
    wire_bytes_ici: float
    wire_bytes_dcn: float
    model_flops: float
    chips: int

    @property
    def collective_s(self) -> float:
        return self.collective_ici_s + self.collective_dcn_s

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        return self.model_flops / self.hlo_flops_global if self.hlo_flops_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """(useful compute time) / (achievable step time lower bound)."""
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        t = self.step_time_lower_bound_s
        return useful_s / t if t > 0 else 0.0

    def to_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_ici_s": self.collective_ici_s,
            "collective_dcn_s": self.collective_dcn_s,
            "hlo_flops_global": self.hlo_flops_global,
            "hlo_bytes_global": self.hlo_bytes_global,
            "wire_bytes_ici": self.wire_bytes_ici,
            "wire_bytes_dcn": self.wire_bytes_dcn,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(
    hlo_flops_global: float,
    hlo_bytes_global: float,
    collectives: CollectiveReport,
    chips: int,
    model_flops: float,
    intra_pod: int = 256,
) -> RooflineTerms:
    wire_ici = collectives.total_wire_bytes(dcn=False)
    wire_dcn = collectives.total_wire_bytes(dcn=True)
    return RooflineTerms(
        compute_s=hlo_flops_global / (chips * PEAK_FLOPS),
        memory_s=hlo_bytes_global / (chips * HBM_BW),
        collective_ici_s=wire_ici / ICI_BW,
        collective_dcn_s=wire_dcn / DCN_BW,
        hlo_flops_global=hlo_flops_global,
        hlo_bytes_global=hlo_bytes_global,
        wire_bytes_ici=wire_ici,
        wire_bytes_dcn=wire_dcn,
        model_flops=model_flops,
        chips=chips,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N_active·D for a forward-only
    step (prefill), 2·N_active per token for decode."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def ssm_scan_addendum(cfg, shape, accum_trips: int = 1) -> Tuple[float, float]:
    """(flops, bytes) of the mamba time-scan interior that loop-once HLO
    accounting misses.  Per step & channel & state: ~6 flops (exp, 2 mul-add
    into h, mul-add into y) on [B, d_in, N] f32."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0, 0.0
    n_mamba = sum(1 for l in range(cfg.n_layers) if not cfg.is_attn_layer(l))
    if shape.kind == "decode":
        steps = 1
        bsz = shape.global_batch
    else:
        steps = shape.seq_len
        bsz = shape.global_batch
    per_step = bsz * cfg.d_inner * cfg.ssm_state
    flops = 6.0 * per_step * steps * n_mamba
    fwd_bwd = 3.0 if shape.kind == "train" else 1.0
    flops *= fwd_bwd
    bytes_ = 4.0 * 4 * per_step * steps * n_mamba * fwd_bwd  # h rw + inputs
    return flops, bytes_
