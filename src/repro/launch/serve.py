"""Serving launcher: multi-replica cluster with BASS request routing.

Spins up N in-process ``ServeEngine`` replicas of a (reduced) model and
drives a batch of requests through the ``BassRouter`` — prefix-warm
requests stick to their home replica unless bandwidth + backlog make a
migration strictly faster (Algorithm 1 Case 1.2), cold requests go to the
least-loaded replica with TS-reserved context transfer (Case 2).

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --smoke \
        --replicas 2 --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_NAMES, get_config
from ..models.model import Model
from ..serving import BassRouter, Request, ServeEngine
from .train import TINY


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="", choices=[""] + ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True) if args.arch else TINY
    cfg = cfg.with_(remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    names = [f"pod0/host{i}" for i in range(args.replicas)]
    engines = {
        n: ServeEngine(model, params, args.slots, args.s_max, name=n) for n in names
    }
    router = BassRouter(names)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    pending = []
    for rid in range(args.requests):
        prompt = rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        req = Request(
            rid=rid, prompt=prompt, max_new=args.max_new,
            prefix_hash=int(rid % max(args.requests // 3, 1)),
        )
        decision = router.route(req)
        admitted = engines[decision.replica].admit(req)
        print(
            f"req {rid:3d} -> {decision.replica} "
            f"(migrated_from={decision.migrated_from}, admitted={admitted}, "
            f"slots={decision.slots[:4]}…)" ,
            flush=True,
        )
        if not admitted:
            pending.append((req, decision.replica))

    done = 0
    while done < args.requests:
        for name, eng in engines.items():
            for req in eng.tick():
                done += 1
                print(
                    f"req {req.rid:3d} finished on {name}: "
                    f"{len(req.tokens_out)} tokens",
                    flush=True,
                )
        router.update_backlog({n: e.backlog_seconds() for n, e in engines.items()})
        still = []
        for req, target in pending:
            if engines[target].admit(req):
                continue
            still.append((req, target))
        pending = still
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests / {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)", flush=True)


if __name__ == "__main__":
    main()
