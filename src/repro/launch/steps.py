"""Step builders: train (with microbatch gradient accumulation), prefill,
decode.  Every inner loop is wrapped in a ``jax.named_scope`` whose label the
HLO roofline analyzer maps to a trip count (``scan_accum``, ``scan_layers``,
``scan_time``, ``scan_qchunk``)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..models.model import Model
from ..optim.adamw import AdamW, AdamWState

Tree = Any


def make_train_step(
    model: Model,
    optimizer: AdamW,
    accum: int = 1,
    microbatch_constraint: Optional[Callable[[Tree], Tree]] = None,
    accum_dtype=jnp.float32,
):
    """→ train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_dtype`` controls the gradient-accumulation buffer: f32 default;
    bf16 halves the largest while-carry for memory-edge cells (≥8 summands
    at loss scale ~1 keeps the rounding error well under the gradient
    noise floor).
    """

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]):
        if accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                gsum, lsum = carry
                if microbatch_constraint is not None:
                    mb = microbatch_constraint(mb)
                (l, _m), g = grad_fn(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g
                )
                return (gsum, lsum + l), None

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            with jax.named_scope("scan_accum"):
                (gsum, lsum), _ = jax.lax.scan(
                    body, (gzero, jnp.zeros((), jnp.float32)), micro
                )
            grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {}

        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(model: Model, s_max: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, s_max)

    return prefill_step


def make_decode_step(model: Model):
    def serve_step(params, token, pos, caches):
        return model.decode(params, token, pos, caches)

    return serve_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics | {"loss": loss}

    return eval_step
