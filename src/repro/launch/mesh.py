"""Production meshes.

Kept as functions (never module-level constants) so importing this module
does not touch jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init, smoke tests see the real single device.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types parameter
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = min(data, n)
    return _make_mesh(
        (data, max(1, min(model, n // data))), ("data", "model")
    )


def mesh_device_count(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
