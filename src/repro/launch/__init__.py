"""Launchers: production mesh, dry-run driver, training and serving CLIs."""
