"""Training launcher — the end-to-end driver wiring every subsystem:

data pipeline (+ BASS shard placement) → sharded train step (pjit) →
AdamW → async checkpointing (Q3) → heartbeat/elastic supervision →
cross-pod sync scheduling (Q1).

On this CPU container it runs real (reduced) models — ``--preset tiny`` is
what the e2e example exercises; ``--arch <assigned>`` selects any of the
ten architecture configs (full size only makes sense on a real fleet; pass
``--smoke`` to use each arch's reduced variant).

Example::

    PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import ARCH_NAMES, get_config
from ..configs.base import ModelConfig
from ..core.topology import tpu_dcn_fabric
from ..data import DataConfig, SyntheticLM, plan_epoch, uniform_shards
from ..models.model import Model
from ..optim import AdamW, warmup_cosine
from ..runtime import HeartbeatMonitor, ProgressTracker
from .mesh import make_smoke_mesh
from .steps import make_train_step

TINY = ModelConfig(
    name="tiny",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab_size=512,
)

PRESET_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32_768,
)


def build_cfg(args) -> ModelConfig:
    if args.arch:
        return get_config(args.arch, smoke=args.smoke)
    return {"tiny": TINY, "100m": PRESET_100M}[args.preset]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="", choices=[""] + ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = build_cfg(args)
    model = Model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}", flush=True)

    # --- data + BASS shard placement (control plane) -------------------------
    dcfg = DataConfig(
        seq_len=args.seq,
        global_batch=args.batch,
        vocab_size=cfg.vocab_size,
        seed=args.seed,
        n_vision_tokens=cfg.n_vision_tokens,
        d_model=cfg.d_model,
        family=cfg.family,
        enc_seq=cfg.enc_seq,
    )
    source = SyntheticLM(dcfg)
    fabric = tpu_dcn_fabric(n_pods=1, hosts_per_pod=4)
    hosts = [f"pod0/host{i}" for i in range(4)]
    shards = uniform_shards(16, hosts, size_bytes=64e6, replication=2)
    assigns, plan = plan_epoch(fabric, hosts, {h: 0.0 for h in hosts}, shards)
    local = sum(1 for a in assigns if a.source is None)
    print(f"BASS shard placement: {len(assigns)} shards, {local} local, "
          f"epoch ingest makespan {plan.makespan:.2f}s", flush=True)

    # --- model/optimizer state ------------------------------------------------
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    opt = AdamW(lr=warmup_cosine(args.lr, max(args.steps // 20, 5), args.steps))
    opt_state = opt.init(params)
    step0 = 0

    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        if args.resume and ckpt.latest_step() is not None:
            step0, (params, opt_state) = ckpt.restore((params, opt_state))
            print(f"resumed from step {step0}", flush=True)

    train_step = jax.jit(
        make_train_step(model, opt, accum=args.accum), donate_argnums=(0, 1)
    )

    # --- supervision ------------------------------------------------------------
    monitor = HeartbeatMonitor(hosts, grace_s=60.0)
    tracker = ProgressTracker()

    t0 = time.time()
    tokens_done = 0
    for step in range(step0, args.steps):
        batch = {k: jnp.asarray(v) for k, v in source.batch(step).items()}
        if "vision_embeds" in batch:
            batch["vision_embeds"] = batch["vision_embeds"].astype(jnp.bfloat16)
        if "frames" in batch:
            batch["frames"] = batch["frames"].astype(jnp.bfloat16)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        for h in hosts:
            monitor.beat(h)
        tokens_done += args.batch * args.seq
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            tps = tokens_done / max(time.time() - t0, 1e-6)
            print(
                f"step {step:5d} loss {loss:8.4f} gnorm {gn:7.3f} "
                f"tok/s {tps:9.0f}",
                flush=True,
            )
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state))
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt_state), blocking=True)
    print("done.", flush=True)


if __name__ == "__main__":
    main()
