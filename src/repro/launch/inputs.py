"""``input_specs`` — ShapeDtypeStruct stand-ins for every (arch × shape) cell.

No device allocation ever happens here; the dry-run lowers directly from
these.  Modality stubs per the assignment: vlm gets precomputed patch
embeddings, audio gets precomputed frame embeddings — both consume part of
the assigned sequence so the *total* token count per cell is exactly the
assigned ``seq_len × global_batch``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs.base import ModelConfig, ShapeSpec
from ..distributed.sharding import spec_for, ACT_RULES_TRAIN, ACT_RULES_DECODE
from ..models.model import Model

Tree = Any


def _batch_axes(mesh):
    return ("pod", "data") if (mesh is not None and "pod" in mesh.shape) else ("data",)


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Tuple[Tree, Tree]:
    """→ (abstract batch, shardings) for the training step."""
    gb, s = shape.global_batch, shape.seq_len
    rules = dict(ACT_RULES_TRAIN)
    rules["batch"] = _batch_axes(mesh)
    batch: Dict[str, Any] = {}
    shard: Dict[str, Any] = {}

    def add(name, shp, dtype, axes):
        batch[name] = _sd(shp, dtype)
        if mesh is not None:
            shard[name] = NamedSharding(mesh, spec_for(shp, axes, mesh, rules))

    if cfg.family == "vlm":
        s_text = s - cfg.n_vision_tokens
        add("tokens", (gb, s_text), jnp.int32, ("batch", "seq"))
        add(
            "vision_embeds",
            (gb, cfg.n_vision_tokens, cfg.d_model),
            jnp.bfloat16,
            ("batch", None, None),
        )
    elif cfg.family == "encdec":
        add("tokens", (gb, s), jnp.int32, ("batch", "seq"))
        add(
            "frames",
            (gb, cfg.enc_seq, cfg.d_model),
            jnp.bfloat16,
            ("batch", "seq", None),
        )
    else:
        add("tokens", (gb, s), jnp.int32, ("batch", "seq"))
    return batch, shard


def prefill_inputs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Tuple[Tree, Tree]:
    return train_inputs(cfg, shape, mesh)


def decode_inputs(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
) -> Tuple[Tree, Tree]:
    """→ (abstract (token, pos, caches), shardings) for one decode step."""
    gb, s_max = shape.global_batch, shape.seq_len
    model = Model(cfg)
    rules = dict(ACT_RULES_DECODE)
    rules["batch"] = _batch_axes(mesh)

    token = _sd((gb, 1), jnp.int32)
    pos = _sd((), jnp.int32)
    token_sh = pos_sh = None
    if mesh is not None:
        token_sh = NamedSharding(mesh, spec_for((gb, 1), ("batch", None), mesh, rules))
        pos_sh = NamedSharding(mesh, PartitionSpec())

    cdefs = model.cache_defs(gb, s_max)
    from ..models.params import tree_map_defs

    caches = tree_map_defs(
        lambda p: _sd(
            p.shape,
            jnp.float32 if ("ssm_state" in p.axes and p.axes[-1] == "ssm_state") else jnp.bfloat16,
        ),
        cdefs,
    )
    cache_sh = None
    if mesh is not None:
        cache_sh = tree_map_defs(
            lambda p: NamedSharding(mesh, spec_for(p.shape, p.axes, mesh, rules)), cdefs
        )
    return (token, pos, caches), (token_sh, pos_sh, cache_sh)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> Tuple[Tree, Tree]:
    if shape.kind == "decode":
        return decode_inputs(cfg, shape, mesh)
    return train_inputs(cfg, shape, mesh)
