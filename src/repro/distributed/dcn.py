"""Cross-pod gradient synchronization over DCN, scheduled by BASS.

Inside a pod, XLA's compiled collectives own the ICI links.  *Between*
pods the wire is the data-center network — shared with input-shard
prefetch (Q2) and checkpoint pushes (Q3).  This module gives that hop the
paper's treatment:

* the per-step pod all-reduce is a known-size flow (grad bytes / pod),
  registered with the BASS controller as a Q1 (highest-priority) transfer
  whose TS slots are reserved on the pod trunks *for the projected step
  cadence* — Pre-BASS-style, slots are booked one step ahead so the flow
  never waits;
* optional int8 error-feedback compression (``grad_compress``) shrinks the
  flow 4× when the DCN term dominates the roofline;
* ``shard_map``-based ``cross_pod_allreduce`` implements the hierarchical
  reduce: reduce-scatter (ICI) → pod all-reduce (DCN) → all-gather (ICI),
  which is also what the compiled train step produces when lowered on the
  (pod, data, model) mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.controller import ClusterController
from ..core.timeslot import TimeSlotLedger, TransferPlan
from ..core.topology import Fabric, storage_hosts, tpu_dcn_fabric

Tree = Any


def cross_pod_allreduce(x: jax.Array, mesh: Mesh, compressed: bool = False):
    """All-reduce ``x`` over the ``pod`` axis via shard_map (DCN hop only).

    With ``compressed=True`` the payload crosses the pod axis as int8 +
    per-block scales (error feedback is applied by the caller, which owns
    the residual state)."""
    from jax.experimental.shard_map import shard_map

    from .grad_compress import compress, decompress

    def body(xs):
        if not compressed:
            return jax.lax.psum(xs, "pod")
        # Quantized all-reduce = all-gather the (int8 payload, scales) pairs
        # and sum the decompressed values: exact sum of per-pod
        # approximations, int8 bytes on the wire.
        q, scale = compress(xs)
        qg = jax.lax.all_gather(q, "pod")            # [P, blocks, B] int8
        sg = jax.lax.all_gather(scale, "pod")        # [P, blocks] f32
        vals = (qg.astype(jnp.float32) * sg[..., None]).sum(axis=0)
        flat = vals.reshape(-1)
        n = 1
        for d in xs.shape:
            n *= d
        return flat[:n].reshape(xs.shape)

    spec = P(*((None,) * x.ndim))
    return shard_map(
        body, mesh=mesh, in_specs=spec, out_specs=spec, check_rep=False
    )(x)


@dataclass
class StepFlow:
    """One scheduled cross-pod flow (grad sync for step N)."""

    step: int
    plan: TransferPlan
    bytes: float


class CrossPodSync:
    """BASS-side bookkeeping for the recurring gradient flow.

    The controller holds the DCN fabric + ledger shared with data placement
    and checkpoint traffic; each training step's sync is reserved ahead of
    time (Pre-BASS) at Q1 priority, i.e. other traffic classes see the
    residual bandwidth only.
    """

    def __init__(
        self,
        fabric: Optional[Fabric] = None,
        n_pods: int = 2,
        hosts_per_pod: int = 64,
        grad_bytes: float = 0.0,
        compress: bool = False,
        slot_duration: float = 0.05,
    ):
        self.fabric = fabric or tpu_dcn_fabric(n_pods, hosts_per_pod)
        # The DCN ledger is the controller's: gradient sync shares it with
        # input-shard placement (Q2) and checkpoint pushes (Q3).
        self.controller = ClusterController(
            self.fabric,
            storage_hosts(self.fabric),
            "bass",
            slot_duration=slot_duration,
            horizon_slots=4096,
        )
        self.ledger = self.controller.state.ledger
        self.n_pods = n_pods
        self.compress = compress
        self.grad_bytes = grad_bytes
        self.flows: Dict[int, StepFlow] = {}

    def wire_bytes(self) -> float:
        eff = self.grad_bytes / 4.0 if self.compress else self.grad_bytes
        return 2.0 * eff * (self.n_pods - 1) / self.n_pods

    def _trunks(self) -> list:
        return [f"pod{p}/trunk" for p in range(self.n_pods)]

    def reserve_step(self, step: int, not_before: float) -> StepFlow:
        """Book TS slots on the pod trunks for step ``step``'s sync."""
        rows = self.ledger.rows(self._trunks())
        size = self.wire_bytes()
        plan = self.ledger.plan_transfer(size, rows, not_before=not_before)
        self.ledger.commit(plan)
        flow = StepFlow(step, plan, size)
        self.flows[step] = flow
        return flow

    def register_steps(
        self,
        first_step: int,
        n_steps: int,
        cadence_s: float,
        start_time: float = 0.0,
    ) -> None:
        """Register the next ``n_steps`` syncs as recurring controller
        events at the projected step cadence — Pre-BASS-style, each step's
        slots are booked when its event fires, one step ahead of the
        compute that needs them.  Drive with :meth:`advance_to`.
        """
        size = self.wire_bytes()
        for k in range(n_steps):
            step = first_step + k
            self.controller.reserve_transfer_at(
                start_time + k * cadence_s, size, self._trunks(), tag=step
            )

    def advance_to(self, t: float) -> Dict[int, StepFlow]:
        """Fire every registered sync event with cadence time ≤ ``t``;
        returns the newly materialized per-step flows.

        Also refreshes steps whose plan the controller replaced — a trunk
        failure suspends the flow's unconsumed remainder and recovery
        re-plans it, so the controller-side plan is authoritative."""
        before = set(self.flows)
        self.controller.run_until(t)
        size = self.wire_bytes()
        for tag, plan in self.controller.flows.items():
            if not isinstance(tag, int):
                continue
            cur = self.flows.get(tag)
            if cur is None or cur.plan is not plan:
                self.flows[tag] = StepFlow(tag, plan, size)
        return {s: f for s, f in self.flows.items() if s not in before}

    # -- network churn (SDN data plane) ------------------------------------
    def fail_link(self, name: str, at: Optional[float] = None) -> None:
        """A DCN trunk died: the in-flight sync's unconsumed slots are
        released and its remainder suspends until :meth:`recover_link`
        (explicit-link flows cannot detour — a pod trunk has no sibling)."""
        self.controller.fail_link(name, at=at)
        self.controller.run_until(self.controller.now)

    def recover_link(self, name: str, at: Optional[float] = None) -> None:
        self.controller.recover_link(name, at=at)
        self.controller.run_until(self.controller.now)

    def projected_sync_seconds(self) -> float:
        """What the reservation implies for the roofline's DCN term."""
        rows = self.ledger.rows(self._trunks())
        bw = self.ledger.path_bandwidth(rows, 0.0)
        return self.wire_bytes() / bw if bw > 0 else float("inf")
