"""Gradient compression for the cross-pod (DCN) hop — error feedback int8.

Intra-pod gradient reduction rides ICI and stays uncompressed.  The pod
axis crosses DCN (~6 GB/s/chip vs ~50 GB/s ICI), so the pod all-reduce is
the slow wire; compressing *only that hop* cuts its bytes 4× (int8 + f32
scale per block) while error feedback keeps the sequence of updates
unbiased in the long run (residual carried to the next step).

Used by ``dcn.CrossPodSync``: reduce-scatter intra-pod (f32) → compress →
pod all-reduce (int8) → decompress → all-gather intra-pod.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any
BLOCK = 1024


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """→ (int8 payload [n/B, B], f32 per-block scales [n/B])."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_with_feedback(
    x: jax.Array, residual: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error feedback: compress (x + residual), carry the quantization error.

    → (payload, scales, new_residual)."""
    target = x.astype(jnp.float32) + residual
    q, scale = compress(target)
    approx = decompress(q, scale, x.shape)
    return q, scale, target - approx


def tree_compress_with_feedback(grads: Tree, residuals: Tree):
    qs, scales, new_res = [], [], []
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    for g, r in zip(flat_g, flat_r):
        q, s, nr = compress_with_feedback(g, r)
        qs.append(q)
        scales.append(s)
        new_res.append(nr)
    unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return unf(qs), unf(scales), unf(new_res)


def tree_decompress(qs: Tree, scales: Tree, template: Tree) -> Tree:
    flat_q, treedef = jax.tree_util.tree_flatten(qs)
    flat_s = treedef.flatten_up_to(scales)
    flat_t = treedef.flatten_up_to(template)
    out = [
        decompress(q, s, t.shape) for q, s, t in zip(flat_q, flat_s, flat_t)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)
