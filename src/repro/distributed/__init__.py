"""Distribution: sharding rules, gradient compression, cross-pod DCN sync."""
from .sharding import (
    ACT_RULES_DECODE,
    ACT_RULES_TRAIN,
    PARAM_RULES,
    cache_shardings,
    param_shardings,
    replication_report,
    spec_for,
)
