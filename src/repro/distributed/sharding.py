"""Logical-axis → mesh-axis sharding rules.

Models declare *logical* axes (``d_model``, ``heads``, ``d_ff``, ``experts``
…); this module maps them onto the production mesh:

* ``model`` axis (16-way, intra-pod ICI): tensor parallel — heads / d_ff /
  vocab / experts / d_inner; KV-cache *sequence* for decode (flash-decode
  style — works for every GQA width incl. kv_heads < 16).
* ``data`` axis (16-way): batch; FSDP for parameters on ``d_model`` (ZeRO-3
  style — weights gathered per layer inside the scan, grads reduce-scattered).
* ``pod`` axis (2-way, DCN): pure data parallel — batch only; parameters are
  replicated across pods and gradient sync over DCN is scheduled by the BASS
  controller (see ``distributed.dcn``).

A logical axis is only sharded when the dimension divides the mesh axis;
otherwise it degrades to replication (e.g. kv_heads=2 on a 16-way model
axis) — recorded so the roofline can call out the waste.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Tree = Any

# logical axis -> mesh axis name, per context
PARAM_RULES: Dict[str, str] = {
    "d_model": "data",          # FSDP
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "d_inner": "model",
}

ACT_RULES_TRAIN: Dict[str, str] = {
    "batch": ("pod", "data"),
    "seq": "model",             # sequence parallelism for long prefill
    "vocab": "model",
}

# §Perf iteration 1: attention computes per-head (a2a seq→heads at the qkv
# projections) instead of re-gathering seq-sharded K/V per chunk.
ACT_RULES_TRAIN_OPT: Dict[str, str] = {
    **ACT_RULES_TRAIN,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",      # §Perf it.4: Megatron MLP (gather x, not weights)
    "megatron_blocks": True,  # §Perf it.5: one bf16 gather per block
}

# §Perf iteration 3: small models (≲1 B params) waste a 16-way tensor axis;
# run pure data parallel over every mesh axis instead (candidate list: full
# product first, then without the pod axis).
ACT_RULES_SMALL_DP: Dict[str, Any] = {
    "batch": [("pod", "data", "model"), ("data", "model"), ("data",)],
}

# Matching parameter policy: replicate everything (a ≲1 B model fits on one
# chip many times over; optimizer state stays sharded over data via the
# optimizer tree's own rules if desired — here full DP keeps it simple).
PARAM_RULES_SMALL_DP: Dict[str, Any] = {}

ACT_RULES_DECODE: Dict[str, str] = {
    "batch": ("pod", "data"),
    "kv_seq": "model",          # flash-decode: shard the KV cache on length
    "d_inner": "model",
    "vocab": "model",
}


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def spec_for(
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    mesh: Mesh,
    rules: Dict[str, Any],
) -> PartitionSpec:
    """Rules values may be a mesh axis, a tuple of axes, or a *list of
    candidates* (first divisible & unused wins — e.g. batch prefers
    ("pod","data","model") and degrades to ("data","model") on meshes whose
    full product doesn't divide the dimension)."""
    entries = []
    used = set()
    for dim, ax in zip(shape, axes):
        rule = rules.get(ax) if ax else None
        if rule is None:
            entries.append(None)
            continue
        candidates = rule if isinstance(rule, list) else [rule]
        chosen = None
        for mesh_axis in candidates:
            key = tuple(mesh_axis) if isinstance(mesh_axis, tuple) else (mesh_axis,)
            if any(k in used for k in key):
                continue  # a mesh axis may appear once per spec
            if any(k not in mesh.shape for k in key):
                continue
            if dim % _axis_size(mesh, mesh_axis) != 0:
                continue  # indivisible → try next candidate
            chosen = mesh_axis
            used.update(key)
            break
        entries.append(chosen)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def param_shardings(defs: Tree, mesh: Mesh, rules=None) -> Tree:
    from ..models.params import tree_map_defs

    rules = PARAM_RULES if rules is None else rules
    return tree_map_defs(
        lambda p: NamedSharding(mesh, spec_for(p.shape, p.axes, mesh, rules)), defs
    )


def cache_shardings(cache_defs: Tree, mesh: Mesh, rules=None) -> Tree:
    from ..models.params import tree_map_defs

    rules = ACT_RULES_DECODE if rules is None else rules
    return tree_map_defs(
        lambda p: NamedSharding(mesh, spec_for(p.shape, p.axes, mesh, rules)), cache_defs
    )


def replication_report(defs: Tree, mesh: Mesh, rules=None) -> Dict[str, int]:
    """Bytes that *failed* to shard per logical axis (roofline callouts)."""
    from ..models.params import P, tree_map_defs

    rules = PARAM_RULES if rules is None else rules
    report: Dict[str, int] = {}

    def visit(p):
        for dim, ax in zip(p.shape, p.axes):
            mesh_axis = rules.get(ax) if ax else None
            if mesh_axis is not None and dim % _axis_size(mesh, mesh_axis) != 0:
                report[ax] = report.get(ax, 0) + 1
        return None

    tree_map_defs(visit, defs)
    return report
