"""Activation-sharding context.

Model code is mesh-agnostic; the launcher establishes a context
(``activation_sharding(mesh, rules)``) during tracing, and layer code calls
``constrain(x, logical_axes)`` at the residual-stream boundaries.  With no
context active (smoke tests, single-device accounting lowering) it is a
no-op, so the same model code serves every environment.

This is what keeps saved-for-backward activations sequence-sharded over the
``model`` axis inside the layer scan (Megatron-SP style): without it XLA
saves full-length activations per layer and the 123 B train cell needs
~76 GiB/device; with it the same cell fits.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import spec_for

_STATE: list = []


@contextmanager
def activation_sharding(mesh: Mesh, rules: Dict[str, Any]):
    _STATE.append((mesh, dict(rules)))
    try:
        yield
    finally:
        _STATE.pop()


def active() -> Optional[Tuple[Mesh, Dict[str, Any]]]:
    return _STATE[-1] if _STATE else None


def constrain(
    x: jax.Array,
    logical_axes: Tuple[Optional[str], ...],
    only_if: Optional[str] = None,
    require_axis: Optional[str] = None,
) -> jax.Array:
    """Apply a sharding constraint from logical axes under the active rules.

    ``only_if`` names a boolean policy flag that must be present in the
    rules (e.g. "megatron_blocks"); ``require_axis`` names a logical axis
    that must be mapped by the rules for the constraint to apply at all —
    otherwise a partially-resolved spec (e.g. batch only) would silently
    force the *other* dims replicated, changing baseline behavior."""
    ctx = active()
    if ctx is None:
        return x
    mesh, rules = ctx
    if only_if is not None and not rules.get(only_if):
        return x
    if require_axis is not None and require_axis not in rules:
        return x
    spec = spec_for(x.shape, logical_axes, mesh, rules)
    if not spec:
        # nothing resolved → leave placement to the partitioner rather than
        # forcing replication (keeps policy deltas strictly additive)
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
