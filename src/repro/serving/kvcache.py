"""Paged KV-cache allocator (vLLM-style), host-side control plane.

The serving engine's dense per-slot cache is fine for a demo; at
production batch sizes the KV pool must be **paged**: fixed-size blocks,
per-sequence page tables, copy-free prefix sharing (the BASS router's
``prefix_hash`` locality is exactly a shared page run), and O(1)
alloc/free so continuous batching never compacts memory.

This module is the allocator + page-table bookkeeping (pure Python, unit
tested); ``gather_pages`` shows the device-side read: a page-table gather
that materializes a sequence's K/V view for attention.  On TPU the same
layout feeds the flash-decode kernel block-by-block (block size == page
size) without materializing anything.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


class OutOfPages(RuntimeError):
    pass


@dataclass
class SeqPages:
    seq_id: int
    pages: List[int] = field(default_factory=list)   # page ids, in order
    length: int = 0                                   # tokens written
    shared_prefix: int = 0                            # leading *shared* pages


class PagedKVCache:
    """Fixed-pool page allocator with refcounted prefix sharing.

    Pages are ``page_size`` tokens; a sequence owns a list of pages; a
    shared prefix is a run of pages with refcount > 1 (copy-on-write on
    first divergent append).
    """

    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages))
        self._ref = np.zeros(n_pages, dtype=np.int32)
        self._seqs: Dict[int, SeqPages] = {}
        self._prefix_index: Dict[int, Tuple[int, ...]] = {}  # hash -> pages

    # -- stats ---------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        return 1.0 - self.free_pages / self.n_pages

    # -- allocation -----------------------------------------------------------
    def _alloc_page(self) -> int:
        if not self._free:
            raise OutOfPages(f"pool exhausted ({self.n_pages} pages)")
        p = self._free.pop()
        self._ref[p] = 1
        return p

    def register_prefix(self, prefix_hash: int, seq_id: int, n_tokens: int) -> None:
        """Publish the first ``n_tokens`` of ``seq_id`` as a shareable prefix."""
        sp = self._seqs[seq_id]
        n_pages = n_tokens // self.page_size         # only whole pages share
        self._prefix_index[prefix_hash] = tuple(sp.pages[:n_pages])

    def allocate(
        self, seq_id: int, n_tokens: int, prefix_hash: Optional[int] = None
    ) -> SeqPages:
        """Reserve pages for a sequence of ``n_tokens`` prompt tokens,
        reusing a published prefix when available (zero-copy)."""
        if seq_id in self._seqs:
            raise ValueError(f"seq {seq_id} already allocated")
        sp = SeqPages(seq_id)
        shared = self._prefix_index.get(prefix_hash) if prefix_hash is not None else None
        remaining = n_tokens
        if shared:
            usable = min(len(shared), n_tokens // self.page_size)
            for p in shared[:usable]:
                self._ref[p] += 1
                sp.pages.append(p)
            sp.shared_prefix = usable
            remaining = n_tokens - usable * self.page_size
        n_new = -(-remaining // self.page_size) if remaining else 0
        try:
            for _ in range(n_new):
                sp.pages.append(self._alloc_page())
        except OutOfPages:
            self._release_pages(sp.pages[sp.shared_prefix:])
            for p in sp.pages[: sp.shared_prefix]:
                self._ref[p] -= 1
            raise
        sp.length = n_tokens
        self._seqs[seq_id] = sp
        return sp

    def append_token(self, seq_id: int) -> int:
        """Account one decoded token; may allocate (or copy-on-write) a page.

        → the page id the token lands in."""
        sp = self._seqs[seq_id]
        page_idx = sp.length // self.page_size
        if page_idx >= len(sp.pages):
            sp.pages.append(self._alloc_page())
        else:
            p = sp.pages[page_idx]
            if self._ref[p] > 1:                      # copy-on-write
                q = self._alloc_page()
                self._ref[p] -= 1
                sp.pages[page_idx] = q
        sp.length += 1
        return sp.pages[page_idx]

    def free(self, seq_id: int) -> None:
        sp = self._seqs.pop(seq_id)
        self._release_pages(sp.pages)

    def _release_pages(self, pages: List[int]) -> None:
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] <= 0:
                self._ref[p] = 0
                self._free.append(p)

    def page_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Padded int32 page table for the device-side gather."""
        sp = self._seqs[seq_id]
        out = np.full(max_pages, -1, dtype=np.int32)
        out[: len(sp.pages)] = sp.pages
        return out


def gather_pages(pool, page_table):
    """Device-side read: pool [P, page, heads, hd] + table [n] → a
    sequence's contiguous KV view [n·page, heads, hd] (invalid pages → 0)."""
    import jax.numpy as jnp

    safe = jnp.maximum(page_table, 0)
    pages = pool[safe]                                 # [n, page, heads, hd]
    mask = (page_table >= 0)[:, None, None, None]
    pages = jnp.where(mask, pages, 0)
    n, ps = pages.shape[0], pages.shape[1]
    return pages.reshape(n * ps, *pages.shape[2:])
