from .engine import Request, ServeEngine
from .kvcache import PagedKVCache, gather_pages
from .router import BassRouter, RouteDecision
