"""Batched serving engine: continuous-batching decode loop on one replica.

``ServeEngine`` owns params + a slot-based KV cache region: requests are
admitted into free slots (prefill), every engine tick decodes one token for
all active slots, finished requests free their slots.  Cluster-level
dispatch across replicas is ``router.BassRouter`` — the paper's scheduler
deciding *which replica* serves a request based on prefix locality, queue
backlog and the bandwidth needed to migrate context.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import Model

Tree = Any


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [S] int32
    max_new: int
    prefix_hash: int = 0             # locality key for the router
    submitted_at: float = field(default_factory=time.monotonic)
    tokens_out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: Tree,
        slots: int,
        s_max: int,
        name: str = "replica0",
    ):
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.slots = slots
        self.s_max = s_max
        self.name = name
        self.active: Dict[int, Request] = {}      # slot -> request
        self._free = list(range(slots))
        self._caches = model.init_caches(slots, s_max)
        self._pos = np.zeros(slots, dtype=np.int32)
        self._decode = jax.jit(model.decode, donate_argnums=(3,))
        self._prefill = jax.jit(
            lambda p, b, s=s_max: model.prefill(p, b, s)
        )

    # -- queueing -------------------------------------------------------------
    def backlog_seconds(self, per_token_s: float = 0.02) -> float:
        """ΥI for the router: projected seconds to drain current work."""
        remaining = sum(
            r.max_new - len(r.tokens_out) for r in self.active.values()
        )
        return remaining * per_token_s

    def has_capacity(self) -> bool:
        return bool(self._free)

    # -- admission --------------------------------------------------------------
    def admit(self, req: Request) -> bool:
        if not self._free:
            return False
        slot = self._free.pop(0)
        # Single-sequence prefill into this slot's cache region.
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (1, self.cfg.n_vision_tokens, self.cfg.d_model), jnp.bfloat16
            )
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_seq, self.cfg.d_model), jnp.bfloat16
            )
        logits, caches1 = self._prefill(self.params, batch)
        # Write the single-sequence cache into the slot of the batched cache.
        self._caches = _write_slot(self._caches, caches1, slot)
        first = int(jnp.argmax(logits[0]))
        req.tokens_out.append(first)
        n_prefix = self.cfg.n_vision_tokens if self.cfg.family == "vlm" else 0
        self._pos[slot] = len(req.prompt) + n_prefix
        self.active[slot] = req
        return True

    # -- decode tick --------------------------------------------------------------
    def tick(self) -> List[Request]:
        """One decode step for all active slots; → finished requests."""
        if not self.active:
            return []
        tokens = np.zeros((self.slots, 1), dtype=np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.tokens_out[-1]
        # Uniform position per step keeps the step jit-compiled once; slots
        # with shorter contexts simply have masked-out upper positions.
        pos = int(self._pos.max())
        logits, self._caches = self._decode(
            self.params, jnp.asarray(tokens), jnp.int32(pos), self._caches
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in list(self.active.items()):
            req.tokens_out.append(int(nxt[slot]))
            self._pos[slot] += 1
            if len(req.tokens_out) >= req.max_new or self._pos[slot] >= self.s_max - 1:
                req.done = True
                finished.append(req)
                del self.active[slot]
                self._free.append(slot)
        return finished


def _write_slot(batched: Tree, single: Tree, slot: int) -> Tree:
    """Place a 1-batch cache tree into slot ``slot`` of the batched tree.

    Cache leaves are stacked [L, B, ...]; batch is dim 1.
    """
    def wr(b, s):
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype), slot, axis=1)

    return jax.tree_util.tree_map(wr, batched, single)
