"""BASS request router — the paper's scheduler at the serving layer.

The mapping is one-to-one with Algorithm 1:

* ``ND_loc``    — replica(s) holding a warm prefix/KV for the request's
  ``prefix_hash`` (data locality: reusing the cache skips prefill compute
  *and* context transfer);
* ``ΥI_j``      — per-replica backlog seconds (ProgressRate-style estimate
  from the engines);
* ``TM``        — context-migration time: moving the prompt/KV bytes to a
  less-loaded replica through the DCN, against the live TS ledger;
* Case 1.2     — migrate iff the bandwidth exists to make the remote
  completion strictly earlier; reserve the slots when we do;
* Case 2       — cold prefixes go to ``ND_minnow`` with a reservation.

The router and the training-side shard placement share ``core`` — one
scheduler, two surfaces, exactly the paper's "global view" point.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.controller import BassPolicy, ClusterController
from ..core.qos import TenantBook, TenantSpec
from ..core.tasks import Assignment, Task
from ..core.topology import Fabric, tpu_dcn_fabric
from .engine import Request

#: Backlog surcharge (seconds) pricing an unreachable replica out of the
#: minnow choice while it is partitioned from the fabric.
_DEAD_BACKLOG_S = 1e15


@dataclass
class RouteDecision:
    rid: int
    replica: str
    migrated_from: Optional[str]
    ready_at: float
    slots: Tuple[int, ...]
    #: True when every replica stayed unreachable through the retry window:
    #: nothing was committed, ``ready_at`` is +inf, and ``replica`` is only
    #: a parking hint (the coldest configured replica) — shed or requeue.
    degraded: bool = False
    #: True when tenant admission control turned the request away before
    #: any scheduling work: nothing committed, ``replica`` is empty.
    rejected: bool = False


class BassRouter:
    def __init__(
        self,
        replicas: Sequence[str],
        fabric: Optional[Fabric] = None,
        decode_s_per_token: float = 0.02,
        bytes_per_ctx_token: float = 2 * 8 * 128 * 2,  # kv bf16, 8 heads × 128
        slot_duration: float = 0.05,
        nic_bytes_per_s: float = 25e9,
        max_retries: int = 3,
        retry_backoff_s: float = 0.05,
        controller=None,
        tenants: Sequence["TenantSpec"] = (),
        fairness_slack_s: float = 1.0,
    ):
        #: Transient all-replicas-dead windows (mid-failover) are retried
        #: with exponential sim-time backoff before degrading — a router
        #: that propagates UnroutableError turns a 50 ms blip into a
        #: caller-visible crash.
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.replicas = list(replicas)
        if controller is not None:
            # Injected backend — typically a ``core.hierarchy``
            # HierarchicalController so per-pod replica groups ride the
            # pod-local fast path; any object with the controller surface
            # (state.set_idle, submit/run_until, jobs, dataplane, obs)
            # works.  The caller owns its configuration.
            missing = [r for r in self.replicas
                       if r not in controller.state.idle]
            if missing:
                raise ValueError(
                    f"injected controller does not own replicas: {missing!r}"
                )
            self.controller = controller
            self.fabric = controller.fabric
        else:
            if fabric is None:
                # star fabric over the replica names (25 GB/s NICs)
                fabric = Fabric()
                for i, r in enumerate(self.replicas):
                    fabric.add_uplink(f"nic{i}", r, "agg", nic_bytes_per_s)
            self.fabric = fabric
            # The long-lived controller owns the ledger: every routed
            # request's context migration is a committed TS reservation
            # that later requests (and other traffic on a shared fabric)
            # must respect.
            self.controller = ClusterController(
                self.fabric,
                self.replicas,
                BassPolicy(),
                slot_duration=slot_duration,
                horizon_slots=2048,
            )
        self.ledger = self.controller.state.ledger
        # Per-tenant QoS (core.qos): token-bucket admission + WFQ weighted
        # fairness.  Tenants beyond ``fairness_slack_s`` of weighted
        # service past the fairness frontier lose the migration fast path
        # (pinned data-local, no new boundary reservations) until the
        # frontier catches up.
        self.tenants = TenantBook(tenants) if tenants else None
        self.fairness_slack_s = fairness_slack_s
        # Routing outcomes in the controller's obs registry, so degraded/
        # load-shed decisions show up in Registry.snapshot() alongside the
        # scheduler counters (bench_recovery asserts shed counts here).
        self.stats = self.controller.obs.group(
            "router",
            ("routed", "migrated", "degraded", "retries", "rejected",
             "pinned"),
        )
        self.decode_s_per_token = decode_s_per_token
        self.bytes_per_ctx_token = bytes_per_ctx_token
        self.prefix_home: Dict[int, List[str]] = {}   # prefix_hash -> replicas
        self.backlog: Dict[str, float] = {r: 0.0 for r in self.replicas}

    def update_backlog(self, backlog: Dict[str, float]) -> None:
        self.backlog.update(backlog)

    # -- network churn (SDN data plane) ------------------------------------
    def fail_link(self, name: str) -> None:
        """A replica NIC/fabric link died: reroute in-flight migrations now
        and steer subsequent requests away from unreachable replicas."""
        self.controller.fail_link(name)
        self.controller.run_until(self.controller.now)

    def recover_link(self, name: str) -> None:
        self.controller.recover_link(name)
        self.controller.run_until(self.controller.now)

    def _alive(self, replica: str) -> bool:
        return self.controller.dataplane.host_alive(replica)

    def _tenant_stats(self, tenant: str):
        return self.controller.obs.group(
            f"tenant.{tenant}",
            ("admitted", "rejected", "pinned", "migrated"),
        )

    def route(self, req: Request, now: float = 0.0,
              tenant: Optional[str] = None) -> RouteDecision:
        work_s = req.max_new * self.decode_s_per_token
        tg = None
        if tenant is not None:
            if self.tenants is None:
                raise ValueError(
                    f"request tagged tenant={tenant!r} but the router was "
                    "built without tenants"
                )
            tg = self._tenant_stats(tenant)
            if not self.tenants.admit(tenant, now):
                # Hard admission control: over-rate tenants are turned
                # away before any scheduling work or reservation happens.
                tg["rejected"] += 1
                self.stats["rejected"] += 1
                return RouteDecision(
                    rid=req.rid,
                    replica="",
                    migrated_from=None,
                    ready_at=float("inf"),
                    slots=(),
                    degraded=True,
                    rejected=True,
                )
            tg["admitted"] += 1
        at = max(now, self.controller.now)
        attempt = 0
        while not any(self._alive(r) for r in self.replicas):
            if attempt >= self.max_retries:
                # Degraded mode: every replica stayed unreachable through
                # the whole backoff window.  Commit nothing and surface a
                # non-routable decision instead of raising — parking a
                # request on a partitioned replica would strand it behind
                # the 1e15 s backlog surcharge, and propagating would turn
                # a transient failover window into a caller-visible crash.
                self.stats["degraded"] += 1
                return RouteDecision(
                    rid=req.rid,
                    replica=self._coldest(),
                    migrated_from=None,
                    ready_at=float("inf"),
                    slots=(),
                    degraded=True,
                )
            attempt += 1
            self.stats["retries"] += 1
            # Advance sim time so queued recoveries (link_up/host_up events
            # already on the controller heap) get a chance to fire.
            at += self.retry_backoff_s * (2 ** (attempt - 1))
            self.controller.run_until(at)
        holders = [
            r
            for r in self.prefix_home.get(req.prefix_hash, [])
            if r in self.replicas and self._alive(r)
        ]
        if (tenant is not None
                and self.tenants.lag(tenant) > self.fairness_slack_s + 1e-9):
            # Weighted fairness: this tenant is past its fair share, so it
            # loses the migration fast path — served data-local (coldest
            # holder, or coldest replica on a cold prefix) with no new
            # boundary reservation, leaving the fabric to tenants the
            # fairness frontier still owes service.
            node = (
                min(holders, key=lambda r: (self.backlog.get(r, 0.0), r))
                if holders
                else self._coldest()
            )
            ready = at + self.backlog.get(node, 0.0)
            self.backlog[node] = self.backlog.get(node, 0.0) + work_s
            home = self.prefix_home.setdefault(req.prefix_hash, [])
            if node not in home:
                home.append(node)
            self.tenants.charge(tenant, work_s)
            tg["pinned"] += 1
            self.stats["pinned"] += 1
            self.stats["routed"] += 1
            return RouteDecision(
                rid=req.rid,
                replica=node,
                migrated_from=None,
                ready_at=ready,
                slots=(),
            )
        # Cold prefix: no usable holders — route to the coldest replica
        # (Case 2-style single-holder task; the data is born there).
        task = Task(
            tid=req.rid,
            size=len(req.prompt) * self.bytes_per_ctx_token,
            compute=work_s,
            replicas=tuple(holders) if holders else (self._coldest(),),
        )
        # ΥI_j = engine backlog (ProgressRate-style estimate), refreshed per
        # request; the controller then places the request as a one-task job.
        # Clamp against the controller clock: request timestamps from
        # concurrent frontends may arrive slightly out of order.
        # Unreachable replicas (dead NIC / partitioned) are priced out of the
        # minnow choice instead of removed — recovery needs no rebuild.
        at = max(at, self.controller.now)
        self.controller.state.set_idle(
            {
                r: at + self.backlog.get(r, 0.0)
                if self._alive(r)
                else at + _DEAD_BACKLOG_S
                for r in self.replicas
            }
        )
        jid = self.controller.submit([task], at=at)
        self.controller.run_until(at)
        # The router is a long-lived service: drop the per-request record
        # once read (the ledger keeps the reservations) or memory grows
        # with total request count.
        a = self.controller.jobs.pop(jid).assignments[0]
        self.backlog[a.node] = self.backlog.get(a.node, 0.0) + work_s
        self.prefix_home.setdefault(req.prefix_hash, [])
        if a.node not in self.prefix_home[req.prefix_hash]:
            self.prefix_home[req.prefix_hash].append(a.node)
        self.stats["routed"] += 1
        if a.source is not None:
            self.stats["migrated"] += 1
        if tenant is not None:
            self.tenants.charge(tenant, work_s)
            if a.source is not None:
                tg["migrated"] += 1
        return RouteDecision(
            rid=req.rid,
            replica=a.node,
            migrated_from=a.source,
            ready_at=a.start,
            slots=a.transfer.slots if a.transfer else (),
        )

    def _coldest(self) -> str:
        live = [r for r in self.replicas if self._alive(r)] or self.replicas
        return min(live, key=lambda r: (self.backlog.get(r, 0.0), r))
